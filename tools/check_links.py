#!/usr/bin/env python
"""Fail on broken intra-repo markdown links (the docs CI job).

Scans ``README.md``, ``ROADMAP.md`` and everything under ``docs/`` for
``[text](target)`` links and verifies every non-http target resolves to a
file or directory relative to the linking file (fragment anchors are
stripped; pure-anchor and mailto links are skipped).  Exit code 1 lists
every broken link — a docs site whose internal links rot silently is worse
than none.

    python tools/check_links.py            # repo root inferred
    python tools/check_links.py path/to/repo
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — excluding images is pointless (same rules apply), but
# skip reference-style and code spans by only matching inline links
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def iter_markdown(root: Path):
    yield from (p for p in (root / "docs").glob("**/*.md")
                if (root / "docs").is_dir())
    for name in ("README.md", "ROADMAP.md"):
        p = root / name
        if p.exists():
            yield p


def check_file(md: Path, root: Path) -> list[str]:
    errors = []
    text = md.read_text()
    # strip fenced code blocks: links inside examples aren't navigation
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for match in LINK_RE.finditer(text):
        target = match.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = Path(argv[0]).resolve() if argv else \
        Path(__file__).resolve().parent.parent
    errors = []
    checked = 0
    for md in iter_markdown(root):
        checked += 1
        errors.extend(check_file(md, root))
    for err in errors:
        print(err)
    print(f"checked {checked} markdown files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken links)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
