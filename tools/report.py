#!/usr/bin/env python
"""Replay a telemetry JSONL log into a human-readable run report.

``launch/train.py --log-dir DIR`` (and ``launch/serve.py``, the examples,
``benchmarks/common.write_rows``) all emit one JSONL stream of schema'd
rows (``repro.telemetry.sink.ROW_KINDS``).  This tool is the read side:
it reconstructs, post-hoc and offline,

  * the PBT **family tree** — every evolve row carries ``parents[i]`` =
    the member whose state slot ``i`` now holds, so the full clone
    genealogy of the final population is recoverable;
  * per-member **hyper trajectories** (the time series of ``members``
    rows);
  * per-phase **wall-clock** (iterate / update / evolve / eval / ckpt)
    totals and per-iteration means;
  * **compile events** counted by attribution label (warmup / steady /
    resize / promotion) — recompiles in steady state are a bug report;
  * **serving latency** windows (p50/p99, batch fill, queue depth) and
    the promotion audit trail.

    python tools/report.py /tmp/run/telemetry.jsonl
    python tools/report.py /tmp/run              # dir: finds telemetry.jsonl
    python tools/report.py LOG --check           # schema-validate only (CI)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.telemetry.sink import validate_row  # noqa: E402


# --------------------------------------------------------------- loading
def load_rows(path) -> list[dict]:
    """All rows of a telemetry JSONL file (a directory means its
    ``telemetry.jsonl``), in write order."""
    p = Path(path)
    if p.is_dir():
        p = p / "telemetry.jsonl"
    rows = []
    with open(p) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{p}:{i}: not valid JSON: {e}") from None
    return rows


def check_rows(rows) -> list[str]:
    """Schema errors ('' when valid) — one entry per offending row."""
    errors = []
    for i, row in enumerate(rows, 1):
        err = validate_row(row)
        if err is not None:
            errors.append(f"row {i}: {err}")
    return errors


def by_kind(rows, kind: str) -> list[dict]:
    return [r for r in rows if r.get("kind") == kind]


# --------------------------------------------------------------- lineage
def lineage_tree(rows):
    """Reconstruct the PBT family tree from ``evolve`` rows.

    Nodes are ``(slot, birth_step)`` — a member slot gets a new node
    whenever it receives a new state (step 0 init, or an evolve that
    copies another member / draws fresh).  Returns ``(roots, children,
    current)``: root nodes, a node -> child-nodes map (insertion order),
    and ``current[slot]`` = the live node of each final slot.
    """
    evolves = by_kind(rows, "evolve")
    n = max((len(e["parents"]) for e in evolves), default=0)
    if not n:
        for m in by_kind(rows, "members"):
            for key in ("fitness", "hypers"):
                v = m.get(key)
                if isinstance(v, dict):
                    v = next(iter(v.values()), [])
                if isinstance(v, list):
                    n = max(n, len(v))
    roots = [(i, 0) for i in range(n)]
    children: dict = {node: [] for node in roots}
    current = dict(enumerate(roots))
    for e in evolves:
        step, parents = e["step"], e["parents"]
        prev = dict(current)
        for i, p in enumerate(parents):
            p = int(p)
            if p == i:
                continue                       # survivor: same state line
            node = (i, step)
            children[node] = []
            if p < 0 or p not in prev:
                roots.append(node)             # fresh draw: a new founder
            else:
                children[prev[p]].append(node)
            current[i] = node
    return roots, children, current


def render_tree(roots, children, current, fitness=None) -> list[str]:
    """ASCII family tree; live slots are starred with their final
    fitness."""
    live = {node: slot for slot, node in current.items()}
    lines = []

    def label(node):
        slot, step = node
        s = f"m{slot}@{step}"
        if node in live:
            s += " *"
            if fitness is not None and live[node] < len(fitness):
                s += f" fit={fitness[live[node]]:+.2f}"
        return s

    def walk(node, prefix, tail):
        branch = "" if not prefix and tail is None else \
            ("└─ " if tail else "├─ ")
        lines.append(prefix + branch + label(node))
        kids = children.get(node, [])
        ext = "" if tail is None else ("   " if tail else "│  ")
        for k, kid in enumerate(kids):
            walk(kid, prefix + ext, k == len(kids) - 1)

    for root in roots:
        walk(root, "", None)
    return lines


# ------------------------------------------------------------ summaries
def hyper_trajectories(rows):
    """``{hyper: [(step, [per-member values]), ...]}`` from members
    rows."""
    out: dict[str, list] = {}
    for m in by_kind(rows, "members"):
        for name, vals in (m.get("hypers") or {}).items():
            out.setdefault(name, []).append((m["step"], vals))
    return out


def fitness_series(rows):
    """``[(step, [per-member fitness]), ...]`` from members rows."""
    return [(m["step"], m["fitness"]) for m in by_kind(rows, "members")
            if m.get("fitness") is not None]


def _timer_summary(rows, field):
    out: dict[str, dict] = {}
    for it in by_kind(rows, "iter"):
        for name, secs in (it.get(field) or {}).items():
            d = out.setdefault(name, {"secs": 0.0, "iters": 0})
            d["secs"] += secs
            d["iters"] += 1
    for d in out.values():
        d["secs"] = round(d["secs"], 4)
        d["ms_per_iter"] = round(1e3 * d["secs"] / max(1, d["iters"]), 3)
    return out


def phase_summary(rows):
    """``{phase: {"secs": total, "iters": n, "ms_per_iter": mean}}`` over
    the iter rows' ``phases`` (host DISPATCH time per phase)."""
    return _timer_summary(rows, "phases")


def block_summary(rows):
    """Same aggregation over the iter rows' optional ``blocks`` (host WAIT
    time, ``RunTelemetry.block``).  dispatch ≪ block ≈ wall means the run
    was serial; a small block next to real device work means the wait was
    hidden under enqueued-ahead work (the overlapped engine's signature)."""
    return _timer_summary(rows, "blocks")


def compile_summary(rows):
    """``{label: {"count": n, "secs": total}}`` over compile rows."""
    out: dict[str, dict] = {}
    for c in by_kind(rows, "compile"):
        d = out.setdefault(c["label"], {"count": 0, "secs": 0.0})
        d["count"] += 1
        d["secs"] += c["secs"]
    for d in out.values():
        d["secs"] = round(d["secs"], 4)
    return out


def serve_summary(rows):
    """Aggregate of serve rows: request-weighted latency and fill."""
    serves = by_kind(rows, "serve")
    if not serves:
        return None
    total = sum(s.get("requests", s["count"]) for s in serves)
    return {
        "windows": len(serves),
        "requests": total,
        "p50_ms": round(max(s["p50_ms"] for s in serves), 3),
        "p99_ms": round(max(s["p99_ms"] for s in serves), 3),
        "fill": round(sum(s.get("fill", 1.0) for s in serves)
                      / len(serves), 3),
    }


# ---------------------------------------------------------------- report
def _fmt_members(vals, width: int = 8):
    if not isinstance(vals, list):
        return str(vals)
    return "[" + " ".join(f"{v:+.3g}" if isinstance(v, (int, float))
                          else str(v) for v in vals) + "]"


def report(rows, out=None) -> None:
    # late-bind stdout: a default of ``sys.stdout`` freezes whatever stream
    # is installed at import time (pytest capture, redirects)
    w = (sys.stdout if out is None else out).write
    for run in by_kind(rows, "run"):
        meta = " ".join(f"{k}={v}" for k, v in (run.get("meta") or
                                                {}).items())
        w(f"run {run['run_id']}  jax={run.get('jax')} "
          f"devices={run.get('devices')} ({run.get('platform')})  "
          f"{meta}\n")
    for eng in by_kind(rows, "engine"):
        w("engine: " + " ".join(
            f"{k}={v}" for k, v in eng.items()
            if k not in ("kind", "t")) + "\n")

    phases = phase_summary(rows)
    if phases:
        iters = by_kind(rows, "iter")
        w(f"\nphases ({len(iters)} iterations; dispatch time)\n")
        for name, d in sorted(phases.items(), key=lambda kv:
                              -kv[1]["secs"]):
            w(f"  {name:<10} {d['secs']:>9.3f}s total  "
              f"{d['ms_per_iter']:>9.3f} ms/iter  ({d['iters']} iters)\n")

    blocks = block_summary(rows)
    if blocks:
        w("blocks (block-until-ready wait time; serial: block ≈ wall — "
          "overlapped: collect hides under the update block)\n")
        for name, d in sorted(blocks.items(), key=lambda kv:
                              -kv[1]["secs"]):
            w(f"  {name:<10} {d['secs']:>9.3f}s total  "
              f"{d['ms_per_iter']:>9.3f} ms/iter  ({d['iters']} iters)\n")

    compiles = compile_summary(rows)
    if compiles:
        total = sum(d["count"] for d in compiles.values())
        secs = sum(d["secs"] for d in compiles.values())
        w(f"\ncompiles ({total} events, {secs:.2f}s)\n")
        for label, d in sorted(compiles.items(),
                               key=lambda kv: -kv[1]["secs"]):
            w(f"  {label:<10} {d['count']:>4} x  {d['secs']:>8.3f}s\n")
        steady = compiles.get("steady", {}).get("count", 0)
        if steady:
            w(f"  NOTE: {steady} steady-state recompile(s) — the fused "
              f"call's shapes should be stable after warmup\n")

    ckpts = by_kind(rows, "ckpt")
    if ckpts:
        w(f"\ncheckpoints: {len(ckpts)} saves, "
          f"{sum(c['secs'] for c in ckpts):.3f}s dispatch\n")

    fitness = fitness_series(rows)
    hypers = hyper_trajectories(rows)
    if fitness or hypers:
        w("\npopulation\n")
    for step, vals in fitness:
        w(f"  fitness @{step:<6} {_fmt_members(vals)}\n")
    for name, series in hypers.items():
        w(f"  hyper {name}\n")
        for step, vals in series:
            w(f"    @{step:<6} {_fmt_members(vals)}\n")

    evolves = by_kind(rows, "evolve")
    if evolves:
        w(f"\nlineage ({len(evolves)} evolve events)\n")
        for e in evolves:
            moves = [f"{i}<-{p}" for i, p in enumerate(e["parents"])
                     if int(p) != i]
            w(f"  @{e['step']:<6} {' '.join(moves) if moves else '(no-op)'}"
              + (f"  [{e['strategy']}]" if e.get("strategy") else "")
              + "\n")
        final = fitness[-1][1] if fitness else None
        roots, children, current = lineage_tree(rows)
        w("  family tree (m<slot>@<birth step>; * = in final "
          "population)\n")
        for line in render_tree(roots, children, current, final):
            w("    " + line + "\n")

    srv = serve_summary(rows)
    if srv:
        w(f"\nserving: {srv['requests']} requests over "
          f"{srv['windows']} windows  p50<= {srv['p50_ms']} ms  "
          f"p99<= {srv['p99_ms']} ms  fill {srv['fill']}\n")
    promos = by_kind(rows, "promotion")
    if promos:
        w(f"promotions ({len(promos)})\n")
        for p in promos:
            w(f"  @{p['step']:<6} members={p['members']} "
              f"+{p.get('promoted')} -{p.get('demoted')}\n")

    benches = by_kind(rows, "bench")
    if benches:
        w(f"\nbenchmark rows ({len(benches)})\n")
        for b in benches:
            w("  " + " ".join(f"{k}={v}" for k, v in b.items()
                              if k not in ("kind", "t")) + "\n")

    for end in by_kind(rows, "run_end"):
        w("\nrun_end: " + " ".join(
            f"{k}={v}" for k, v in end.items()
            if k not in ("kind", "t")) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="reconstruct a run report from a telemetry JSONL log")
    ap.add_argument("log", help="telemetry.jsonl (or a --log-dir that "
                    "contains one)")
    ap.add_argument("--check", action="store_true",
                    help="schema-validate every row and exit (CI mode: "
                    "exit 1 on any invalid row)")
    args = ap.parse_args(argv)

    rows = load_rows(args.log)
    errors = check_rows(rows)
    if args.check:
        for e in errors:
            print(e, file=sys.stderr)
        kinds = sorted({r.get("kind") for r in rows})
        print(f"{args.log}: {len(rows)} rows, kinds={kinds}: "
              + ("INVALID" if errors else "OK"))
        return 1 if errors else 0
    if errors:
        print(f"warning: {len(errors)} schema-invalid row(s); "
              f"run --check for details", file=sys.stderr)
    report(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
