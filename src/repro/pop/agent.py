"""The ``Agent`` protocol: what a learner must expose to be population-trained.

The paper's protocol (§4.1) only needs a functional single-agent triple
``init / update / policy``; everything population-shaped (stacking, vmapping,
hyperparameter injection, exploit/explore) is generic machinery layered on
top.  This module pins that contract down and provides adapters for the
learner families in the repo:

  * ``ModuleAgent``       — the functional RL modules (td3 / sac / dqn):
                            per-member state, per-member update.
  * ``PPOAgent``          — the on-policy module (ppo): a ModuleAgent that
                            declares ``experience_kind = "trajectory"`` and
                            exposes the value head the GAE pipeline needs.
  * ``LMAgent``           — the language-model train step: state is
                            (params, opt_state, step), fitness is -loss.
  * ``SharedCriticAgent`` — the §4.2 family (CEM-RL / DvD): ONE critic
                            shared across the population, so the update is
                            inherently population-level (``population_level
                            = True``) and the backend picks between the
                            paper's averaged-loss update and the original
                            CEM-RL sequential ordering.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.population import population_init


@runtime_checkable
class Agent(Protocol):
    """Contract consumed by ``repro.pop`` backends, ``PopTrainer`` and the
    ``repro.rollout`` engine.

    ``population_level`` distinguishes the two update shapes:
      False — ``update(state, batch, hypers)`` is a SINGLE-member step; the
              backend vmaps / loops it over the stacked population.
      True  — ``update`` already consumes the whole stacked population
              (shared-critic family); the backend jits it directly.

    ``experience_kind`` declares what ``batch`` IS (the
    ``repro.data.experience`` protocol) and thereby which fused train
    iteration the rollout engine builds:
      "replay"     — transitions sampled from a FIFO ring (td3/sac/dqn);
      "trajectory" — GAE-processed on-policy minibatches with the extras
                     the acting policy emitted (ppo).  Trajectory agents
                     must additionally expose ``value(actor_params, obs)``
                     (the state-value head GAE bootstraps from) and their
                     ``default_hypers`` provide the ``discount`` /
                     ``gae_lambda`` fallbacks for members that don't tune
                     them.
    """
    population_level: bool
    experience_kind: str

    def population_init(self, key, n: int): ...
    def update(self, state, batch, hypers=None): ...
    def policy(self, actor_params, obs, key=None): ...
    def actor_params(self, pop_state): ...
    def fitness_from_metrics(self, metrics): ...


class AgentBase:
    """Default implementations shared by the adapters."""
    population_level = False
    experience_kind = "replay"

    # The functional RL module whose ``policy`` drives acting-time
    # exploration (``repro.rollout`` builds the exploration policy from its
    # DEFAULT_HYPERS / ``explore``); None means the agent only offers
    # ``policy`` itself.
    exploration_module = None

    @property
    def default_hypers(self) -> dict:
        """Static fallbacks for per-member dynamic hyperparameters (the
        experience pipeline reads ``discount`` / ``gae_lambda`` here)."""
        return {}

    def population_init(self, key, n: int):
        return population_init(self.init, key, n)

    def fitness_from_metrics(self, metrics):
        """Per-member fitness derivable from update metrics, or None when
        fitness comes from the environment (episode returns)."""
        return None

    def gather_members(self, pop_state, parents):
        """PBT exploit: member i adopts member ``parents[i]``'s state."""
        return jax.tree.map(lambda x: x[parents], pop_state)

    # --- evolvable-parameter accessors (used by parameter-space strategies
    # such as CEM; default: the actor params) -----------------------------
    def evolvable_params(self, pop_state):
        return self.actor_params(pop_state)

    def with_evolvable_params(self, pop_state, new_params):
        raise NotImplementedError


class ModuleAgent(AgentBase):
    """Adapter for the functional RL modules (``repro.rl.{td3,sac,dqn}``).

    Any module exposing ``init(key, obs_dim, act_dim, **kw) -> state``,
    ``update(state, batch, hypers) -> (state, metrics)`` and
    ``policy(actor_params, obs, key)`` fits.
    """

    def __init__(self, module, obs_dim: int, act_dim: int, *,
                 actor_field: str | None = None, fused_adam: bool = False,
                 fused_linear: bool = False, **init_kwargs):
        self.module = module
        self.exploration_module = module
        self.obs_dim, self.act_dim = obs_dim, act_dim
        self.init_kwargs = init_kwargs
        self._actor_field = actor_field
        # opt-in population-level optimizer / linear-layer fusion; the
        # PopTrainer flips these when the PopulationConfig says so
        self.fused_adam = fused_adam
        self.fused_linear = fused_linear

    @property
    def default_hypers(self) -> dict:
        return dict(getattr(self.module, "DEFAULT_HYPERS", {}))

    def init(self, key):
        return self.module.init(key, self.obs_dim, self.act_dim,
                                **self.init_kwargs)

    def update(self, state, batch, hypers=None):
        return self.module.update(state, batch, hypers)

    def fused_update(self):
        """The module's POPULATION-level update (optimizer hoisted into
        ``repro.optim.population_adam``, the ``kernels/pop_adam`` path), or
        None when the module doesn't provide one.  Backends route through
        this instead of ``vmap(update)`` when ``fused_adam`` is set."""
        maker = getattr(self.module, "make_population_update", None)
        if maker is None:
            return None
        return maker(fused_linear=self.fused_linear)

    def policy(self, actor_params, obs, key=None):
        return self.module.policy(actor_params, obs, key)

    def _field(self, state) -> str:
        if self._actor_field is None:
            self._actor_field = "actor" if hasattr(state, "actor") else "q"
        return self._actor_field

    def actor_params(self, pop_state):
        return getattr(pop_state, self._field(pop_state))

    def with_evolvable_params(self, pop_state, new_params):
        field = self._field(pop_state)
        repl = {field: new_params}
        target = "target_" + field
        if hasattr(pop_state, target):
            repl[target] = jax.tree.map(jnp.copy, new_params)
        return pop_state._replace(**repl)


class PPOAgent(ModuleAgent):
    """Adapter for ``repro.rl.ppo`` — the repo's on-policy (trajectory)
    agent.

    Same ``init/update/policy`` triple as the other module adapters, so it
    plugs into every vectorized/sequential/islands backend and PBT/CEM
    strategy unchanged; what differs is declared, not special-cased:
    ``experience_kind = "trajectory"`` makes the rollout engine collect
    fixed-length rollouts with the policy's log_prob/value extras, run GAE
    on device, and feed shuffled epoch/minibatches to ``update``.  The
    PBT-tunable per-member hypers are ``lr`` / ``clip_eps`` /
    ``entropy_coef`` (plus ``discount`` / ``gae_lambda`` on the GAE side).
    """
    experience_kind = "trajectory"

    def __init__(self, obs_dim: int, act_dim: int, *, discrete: bool = False,
                 **init_kwargs):
        from repro.rl import ppo
        super().__init__(ppo, obs_dim, act_dim, actor_field="params",
                         discrete=discrete, **init_kwargs)

    def value(self, actor_params, obs):
        """The state-value head GAE bootstraps from (``V(next_obs)`` of
        every stored step, evaluated inside the fused iteration)."""
        return self.module.value(actor_params, obs)


class LMState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray  # per-member step drives the LR schedule; checkpointed


class LMAgent(AgentBase):
    """Adapter for ``repro.models.lm.make_train_step``.

    Per-member PBT hypers are ``lr_scale`` (the paper's LM study) plus
    ``weight_decay`` and ``warmup_frac`` (the Jaderberg et al. LM tuning
    set); fitness is the negative windowed loss.  With
    ``PopulationConfig.fused_adam`` the backends swap the stock
    optax-under-vmap step for ``lm.make_population_update`` (one
    ``population_adam`` application over the flattened population,
    bitwise-equal on fp32 params).  ``model_sharded_params = True`` tells
    the islands layout to apply the ``models/sharding`` rules over each
    island's (data, model) sub-mesh when placing member parameters.
    """

    model_sharded_params = True

    def __init__(self, cfg, tcfg, *, fused_adam: bool = False,
                 fused_linear: bool = False):
        from repro.models import lm as lm_mod
        self.cfg, self.tcfg = cfg, tcfg
        self._lm = lm_mod
        self._init_params = lm_mod.init_params
        self._opt_init, self._train_step = lm_mod.make_train_step(cfg, tcfg)
        # flipped by PopTrainer from the PopulationConfig
        self.fused_adam = fused_adam
        self.fused_linear = fused_linear

    @property
    def default_hypers(self) -> dict:
        return {"lr_scale": 1.0,
                "weight_decay": self.tcfg.weight_decay,
                "warmup_frac": self.tcfg.warmup_steps
                / max(self.tcfg.total_steps, 1)}

    def init(self, key):
        params = self._init_params(key, self.cfg)
        return LMState(params=params, opt_state=self._opt_init(params),
                       step=jnp.zeros((), jnp.int32))

    def update(self, state: LMState, batch, hypers=None):
        h = hypers if hypers else {}
        params, opt_state, metrics = self._train_step(
            state.params, state.opt_state, batch, state.step,
            lr_scale=h.get("lr_scale"),
            weight_decay=h.get("weight_decay"),
            warmup_frac=h.get("warmup_frac"))
        return LMState(params, opt_state, state.step + 1), metrics

    def fused_update(self):
        """Population-level update for the fused_adam path (backend
        registry protocol — same surface as ``ModuleAgent``)."""
        return self._lm.make_population_update(self.cfg, self.tcfg)

    def policy(self, actor_params, obs, key=None):
        raise NotImplementedError("LM agents decode via repro.launch.serve")

    def actor_params(self, pop_state):
        return pop_state.params

    def with_evolvable_params(self, pop_state, new_params):
        return pop_state._replace(params=new_params)

    def fitness_from_metrics(self, metrics):
        return -metrics["loss"]


class SharedCriticAgent(AgentBase):
    """Adapter for the §4.2 shared-critic update (CEM-RL / DvD case studies).

    State is ``repro.core.shared.SharedCriticState``: stacked per-member
    policies + ONE shared critic, so the update consumes the whole
    population at once.  ``dvd_coef_fn`` (set directly or by the ``DvD``
    strategy) enables the determinant diversity term.
    """
    population_level = True

    def __init__(self, obs_dim: int, act_dim: int, *, dvd_coef_fn=None,
                 probe_size: int = 20, train_frac: float = 1.0,
                 fused_adam: bool = False, fused_linear: bool = False):
        from repro.core import shared
        from repro.rl import td3
        self._shared = shared
        self._td3 = td3
        self.exploration_module = td3
        self.obs_dim, self.act_dim = obs_dim, act_dim
        self.dvd_coef_fn = dvd_coef_fn
        self.probe_size = probe_size
        self.train_frac = train_frac
        # opt-in kernels/pop_adam policy step + kernels/pop_matmul member
        # forwards; PopTrainer flips these on when the PopulationConfig
        # says fused_adam / fused_linear = True
        self.fused_adam = fused_adam
        self.fused_linear = fused_linear

    def population_init(self, key, n: int):
        return self._shared.init(key, self.obs_dim, self.act_dim, n)

    def population_update(self, *, sequential: bool = False):
        """The whole-population update fn: the paper's averaged-critic-loss
        form, or the original CEM-RL interleaved ordering (baseline arm)."""
        if sequential:
            return self._shared.sequential_shared_critic_update()
        return self._shared.make_shared_critic_update(
            dvd_coef_fn=self.dvd_coef_fn, probe_size=self.probe_size,
            train_frac=self.train_frac, fused_adam=self.fused_adam,
            fused_linear=self.fused_linear)

    def update(self, state, batch, hypers=None):
        raise TypeError("SharedCriticAgent is population_level; backends "
                        "use population_update() instead of update()")

    def policy(self, actor_params, obs, key=None):
        return self._td3.policy(actor_params, obs, key)

    def actor_params(self, pop_state):
        return pop_state.policies

    def with_evolvable_params(self, pop_state, new_params):
        return pop_state._replace(
            policies=new_params,
            target_policies=jax.tree.map(jnp.copy, new_params))

    def gather_members(self, pop_state, parents):
        """Only the per-member components move; the shared critic (and the
        scalar step/key) have no population axis."""
        take = lambda tree: jax.tree.map(lambda x: x[parents], tree)
        return pop_state._replace(
            policies=take(pop_state.policies),
            target_policies=take(pop_state.target_policies),
            policy_opt=take(pop_state.policy_opt))
