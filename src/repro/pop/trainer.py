"""``PopTrainer`` — the single driver for population (and single-agent)
training.

Composes an ``Agent`` adapter, an ``EvolutionStrategy`` and an
``UpdateBackend`` from one ``PopulationConfig``; population size 1 is just
``NoEvolution`` over a 1-member stack, so every consumer (the LM train CLI,
the RL examples, the benchmarks) runs the same code path.

    agent = ModuleAgent(td3, obs_dim, act_dim)
    pcfg = PopulationConfig(size=8, strategy="pbt", backend="vectorized",
                            hyper_space=space, pbt_interval=10)
    trainer = PopTrainer(agent, pcfg, seed=0)
    for it in ...:
        metrics, lineage = trainer.step(batches, fitness=returns)

Responsibilities:
  * population init (+ strategy binding, e.g. CEM's initial draw)
  * the compiled update (backend + num_steps chaining + buffer donation)
  * the fitness window, CAPPED at ``pcfg.fitness_window`` entries (the
    unbounded-list leak of the old driver is gone)
  * the evolve cadence (every ``pcfg.pbt_interval`` trainer steps; skipped
    entirely for null strategies)
  * checkpoint/resume via ``repro.checkpoint`` (state + strategy internals,
    with hypers and the attached rollout engine's buffers/env states as aux
    trees, plus size + fitness extras — everything
    ``repro.elastic.restore_elastic`` needs to resume on a different
    device count or population size)
  * device placement: ``backend="islands"`` plans (or takes ``layout=``)
    an ``repro.elastic.IslandLayout`` and places state/hypers across it.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PopulationConfig
from repro.pop.backend import UpdateBackend, make_update
from repro.pop.strategy import make_strategy
from repro.telemetry import RunTelemetry


class PopTrainer:
    def __init__(self, agent, pcfg: PopulationConfig | None = None, *,
                 seed: int = 0, key=None, strategy=None, mesh=None,
                 layout=None, checkpoint_dir=None, keep: int = 2,
                 telemetry: RunTelemetry | None = None):
        self.agent = agent
        # the telemetry object is always present (a disabled RunTelemetry
        # when none was passed), so the instrumentation below never
        # branches; all of it is host wall-clock + row dispatch — array
        # values are only ever touched on the sink's writer thread
        self.telemetry = telemetry if telemetry is not None \
            else RunTelemetry(None)
        self.pcfg = pcfg = pcfg if pcfg is not None else PopulationConfig()
        self.n = pcfg.size
        self.key = jax.random.PRNGKey(seed) if key is None else key
        self.strategy = strategy if strategy is not None else \
            make_strategy(pcfg)

        self.key, k_init, k_bind, k_hyp = jax.random.split(self.key, 4)
        self.state = agent.population_init(k_init, self.n)
        if pcfg.fused_adam and hasattr(agent, "fused_adam"):
            # opt-in kernels/pop_adam path: shared-critic agents hoist their
            # policy Adam step, module agents switch to the population-level
            # make_population_update of their rl module
            agent.fused_adam = True
        if pcfg.fused_linear and hasattr(agent, "fused_linear"):
            # opt-in kernels/pop_matmul path for the population-batched
            # linear layers inside the fused update
            agent.fused_linear = True
        self.strategy.configure_agent(agent)
        self.state = self.strategy.bind(k_bind, agent, self.state)
        self.hypers = self.strategy.init_hypers(k_hyp, self.n)

        try:
            backend = UpdateBackend(pcfg.backend)
        except ValueError:
            backend = pcfg.backend
        self.layout = None
        if backend is UpdateBackend.SHARDED:
            from repro.core.distributed import shard_population
            from repro.launch.mesh import make_host_mesh
            self.mesh = mesh if mesh is not None else make_host_mesh(model=1)
            self.state = shard_population(self.state, self.mesh)
        elif backend == "islands":
            from repro.elastic import plan_layout
            self.layout = layout if layout is not None else \
                plan_layout(len(jax.devices()), self.n)
            self.mesh = mesh if mesh is not None else self.layout.mesh
            self.state = self.layout.place(
                self.state,
                model_rules=bool(getattr(agent, "model_sharded_params",
                                         False)))
            if self.hypers is not None:
                self.hypers = self.layout.place(self.hypers)
        else:
            self.mesh = mesh
        self._update = make_update(agent, pcfg.backend,
                                   num_steps=pcfg.num_steps,
                                   donate=pcfg.donate, mesh=self.mesh)

        self._window: deque = deque(maxlen=pcfg.fitness_window)
        self.last_fitness = None  # the (N,) fitness used at the last evolve
        self.step_count = 0
        # LM workloads set tokens_per_step (per-member tokens consumed by
        # one update call); step() then derives a dispatch-rate
        # tokens_per_sec_per_member for the telemetry iter rows.  Host
        # wall-clock between dispatches — no device sync in the hot path
        # (benchmarks/lm_population.py does the blocked measurement).
        self.tokens_per_step = None
        self._iter_t = None
        self._rollout = None
        self._mgr = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            run_meta = {"run_id": self.telemetry.run_id} \
                if self.telemetry.enabled else None
            self._mgr = CheckpointManager(checkpoint_dir, keep=keep,
                                          run_meta=run_meta)
        if self.telemetry.enabled:
            # the step-0 population-health snapshot anchors the hyper
            # trajectories tools/report.py reconstructs
            self.telemetry.record_members(0, hypers=self.hypers)

    # ------------------------------------------------------------------ run
    def step(self, batch, fitness=None):
        """One update call (``pcfg.num_steps`` chained member-steps), plus —
        on cadence — one evolve.  Returns ``(metrics, lineage)`` where
        lineage is None unless evolution ran this step."""
        with self.telemetry.phase("update"):
            self.state, metrics = self._update(self.state, batch,
                                               self.hypers)
        self.step_count += 1
        fit = fitness if fitness is not None \
            else self.agent.fitness_from_metrics(metrics)
        if fit is not None:
            self.report_fitness(fit)
        lineage = self._maybe_evolve()
        extra = {}
        if self.tokens_per_step:
            now = time.perf_counter()
            if self._iter_t is not None and now > self._iter_t:
                extra["tokens_per_sec_per_member"] = \
                    self.tokens_per_step / (now - self._iter_t)
            self._iter_t = now
        self.telemetry.record_iteration(self.step_count - 1, metrics=metrics,
                                        **extra)
        return metrics, lineage

    def run(self, steps: int, batch_fn, *, on_step=None):
        """Drive ``steps`` update calls.  ``batch_fn(step) -> batch``;
        ``on_step(step, metrics, lineage)`` is the logging hook.  Fitness
        comes from the agent's metrics; loops with environment-derived
        fitness call ``step(batch, fitness=...)`` (or ``report_fitness``)
        themselves."""
        metrics = None
        for step in range(self.step_count, steps):
            metrics, lineage = self.step(batch_fn(step))
            if on_step is not None:
                on_step(step, metrics, lineage)
        return metrics

    # ----------------------------------------------------------- env loop
    def attach_rollout(self, env, **engine_kwargs):
        """Attach a ``repro.rollout`` acting engine: per-member batched envs
        (``num_envs``), a population of device-resident experience buffers,
        a deterministic evaluator, and the fused train iteration — shaped
        by the agent's ``experience_kind``: collect->insert->sample->
        ``pcfg.num_steps`` chained updates for replay agents, collect->
        GAE->``epochs`` x shuffled minibatches for trajectory (ppo) agents;
        ``pcfg.backend`` picks the update implementation either way.

        ``policy_lag`` (None, 0 or 1) selects the overlapped engine
        (``repro.rollout.OverlapEngine``): 0 is the split-program parity
        anchor (bitwise-equal to the serial engine), 1 pipelines collect
        against update with one-update-stale acting params.
        ``chunk_steps`` bounds collect memory at GPU-sim env counts
        (either engine).  Returns the engine."""
        from repro.rollout.engine import RolloutEngine
        from repro.rollout.overlap import OverlapEngine
        if self._mgr is not None and self.pcfg.donate:
            raise ValueError(
                "donate=True is unsafe with a checkpoint_dir: save_async "
                "may still be serializing the population state when the "
                "next fused iteration donates (and overwrites) its buffers "
                "— build the PopulationConfig with donate=False")
        policy_lag = engine_kwargs.pop("policy_lag", None)
        self.key, k = jax.random.split(self.key)
        engine_kwargs.setdefault("mesh", self.mesh)
        engine_kwargs.setdefault("telemetry", self.telemetry)
        if policy_lag is None:
            self._rollout = RolloutEngine(self.agent, self.pcfg, env, key=k,
                                          init_state=self.state,
                                          hypers=self.hypers, **engine_kwargs)
        else:
            self._rollout = OverlapEngine(self.agent, self.pcfg, env, key=k,
                                          init_state=self.state,
                                          hypers=self.hypers,
                                          policy_lag=policy_lag,
                                          **engine_kwargs)
        return self._rollout

    @property
    def rollout(self):
        if self._rollout is None:
            raise ValueError("no acting engine: call "
                             "trainer.attach_rollout(env, ...) first")
        return self._rollout

    def env_iteration(self):
        """One fused train iteration (collect + insert + sample +
        ``num_steps`` updates), entirely on device.  Counts as one trainer
        step for the evolve cadence.  Returns ``(metrics, episode_stats,
        did_update)``; updates are skipped (did_update False) until every
        member's buffer can serve a batch."""
        r = self.rollout
        self.key, k = jax.random.split(self.key)
        with self.telemetry.phase("iterate"):
            self.state, metrics, stats, did = r.iterate(self.state,
                                                        self.hypers, k)
        self.step_count += 1
        return metrics, stats, did

    def evaluate_fitness(self):
        """Per-member fitness from deterministic evaluation episodes
        (shape (N,)); does not touch the fitness window."""
        self.key, k = jax.random.split(self.key)
        with self.telemetry.phase("eval"):
            return self.rollout.evaluator.evaluate(self.actors, k)

    def run_env_loop(self, iters: int, *, eval_every: int = 1, on_iter=None,
                     fused: bool = False, block_every: int = 0):
        """Drive ``iters`` fused iterations.  Every ``eval_every`` iterations
        the evaluator scores the population into the fitness window, and —
        exactly like ``step`` — the strategy evolves every
        ``pcfg.pbt_interval`` trainer steps (here: iterations).  CEM's
        Algorithm-1 ordering (train -> evaluate -> refit) falls out of
        ``pbt_interval=1``.  ``on_iter(it, metrics, stats, fitness,
        lineage)`` is the logging hook.  Returns the last (metrics, stats).
        (On-policy engines update from the first iteration — did_update is
        always True; replay engines warm up until buffers can sample.)

        ``fused=True`` runs the SAME loop as whole jitted train–evolve
        epochs (``RolloutEngine.build_epoch``): ``pcfg.pbt_interval``
        iterations + evaluations + the strategy's evolve execute as one
        donated device program per epoch, bit-exact against the eager path
        (``tests/test_fused_epoch.py``), with per-iteration telemetry
        reconstructed from the stacked outputs.  Alignment requirements
        (checked): ``iters`` a multiple of the epoch length, ``eval_every``
        dividing it, the per-epoch evaluation count within
        ``fitness_window``, an epoch-aligned ``step_count`` and an empty
        fitness window when evolution is active.

        ``block_every=N`` (eager loop only) blocks on the iteration's
        metrics every N iterations under ``telemetry.block``, splitting the
        telemetry into dispatch time (``phases``) vs wait time (``blocks``)
        — the instrumentation that makes the overlap win visible: a serial
        engine's block covers the whole iteration, an overlapped engine's
        only the update (acting is already enqueued behind it and is never
        waited on).  Blocking is a measurement choice, so it is off by
        default in the hot path.
        """
        if fused:
            if block_every:
                raise ValueError("block_every instruments the eager loop; "
                                 "fused epochs are one device program")
            return self._run_env_loop_fused(iters, eval_every, on_iter)
        metrics = stats = None
        for it in range(iters):
            metrics, stats, did = self.env_iteration()
            if block_every and (it + 1) % block_every == 0:
                self.telemetry.block("iterate", metrics)
            fitness = None
            if eval_every and (it + 1) % eval_every == 0:
                fitness = self.evaluate_fitness()
                self.report_fitness(fitness)
                self.telemetry.record_members(self.step_count,
                                              fitness=fitness,
                                              hypers=self.hypers)
            lineage = self._maybe_evolve()
            self.telemetry.record_iteration(
                self.step_count - 1, metrics=metrics, stats=stats,
                did_update=did)
            if on_iter is not None:
                on_iter(it, metrics, stats, fitness, lineage)
        return metrics, stats

    def _fused_epoch(self, epoch_len: int, eval_every: int, evolving: bool):
        """The compiled epoch for this shape, built once and cached (a new
        trace per distinct (epoch_len, eval_every, evolving) triple only —
        steady-state epochs re-enter the same executable)."""
        key = (epoch_len, eval_every, evolving)
        cache = getattr(self, "_epoch_cache", None)
        if cache is None:
            cache = self._epoch_cache = {}
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = self.rollout.build_epoch(
                epoch_len=epoch_len, eval_every=eval_every,
                evolve_fn=self.strategy.evolve_jit() if evolving else None,
                donate=self.pcfg.donate)
        return fn

    def _run_env_loop_fused(self, iters: int, eval_every: int, on_iter):
        r = self.rollout
        pbt = self.pcfg.pbt_interval
        evolving = bool(not self.strategy.null and pbt and iters >= pbt)
        if evolving:
            epoch_len = pbt
            if iters % epoch_len:
                raise ValueError(
                    f"fused train–evolve epochs need iters ({iters}) to be "
                    f"a multiple of pbt_interval ({epoch_len})")
            if not eval_every or epoch_len % eval_every:
                raise ValueError(
                    f"fused train–evolve epochs need eval_every "
                    f"({eval_every}) to divide pbt_interval ({epoch_len}) "
                    f"so every epoch scores the population before evolving")
            if epoch_len // eval_every > self.pcfg.fitness_window:
                raise ValueError(
                    f"{epoch_len // eval_every} evaluations per epoch "
                    f"overflow fitness_window={self.pcfg.fitness_window}: "
                    f"the eager loop would drop early rows and diverge")
            if self.step_count % epoch_len:
                raise ValueError(
                    f"step_count={self.step_count} is not epoch-aligned "
                    f"(pbt_interval={epoch_len}); the eager cadence would "
                    f"evolve mid-epoch")
            if self._window:
                raise ValueError(
                    "fitness window is non-empty at fused-epoch entry; the "
                    "eager loop would mix pre-epoch rows into the evolve "
                    "fitness")
        else:
            epoch_len = iters
            if (not self.strategy.null and pbt and eval_every
                    and (self.step_count + iters) // pbt
                    > self.step_count // pbt):
                raise ValueError(
                    f"iters={iters} from step {self.step_count} crosses an "
                    f"evolve boundary (pbt_interval={pbt}) mid-epoch; run "
                    f"a multiple of pbt_interval instead")
        n_evals = (epoch_len // eval_every) if eval_every else 0

        epoch_fn = self._fused_epoch(epoch_len, eval_every, evolving)
        metrics = stats = None
        start = self.step_count
        for _ in range(max(1, iters // epoch_len) if epoch_len else 0):
            base = self.step_count
            hypers_before = self.hypers
            with self.telemetry.phase("epoch"):
                (self.state, r.bufs, r.vstate, new_hypers, strat_state,
                 self.key, m_stack, s_stack, dids, evals, fitness,
                 lineage) = epoch_fn(self.state, r.bufs, r.vstate,
                                     self.hypers,
                                     self.strategy.export_state(), self.key)
            self.step_count += epoch_len
            # per-iteration bookkeeping slices the stacked outputs with
            # python index constants — host-to-device uploads of an int32
            # each, never a device sync.  Scope-allow them so the whole
            # loop still runs under transfer_guard("disallow") (the
            # device-to-host direction stays guarded: nothing here fetches)
            with jax.transfer_guard_host_to_device("allow"):
                self._fused_epoch_bookkeeping(
                    base, start, epoch_len, eval_every, n_evals, evolving,
                    hypers_before, new_hypers, strat_state, m_stack,
                    s_stack, dids, evals, fitness, lineage, on_iter)
                metrics = jax.tree.map(lambda x: x[-1], m_stack)
                stats = jax.tree.map(lambda x: x[-1], s_stack)
        return metrics, stats

    def _fused_epoch_bookkeeping(self, base, start, epoch_len, eval_every,
                                 n_evals, evolving, hypers_before,
                                 new_hypers, strat_state, m_stack, s_stack,
                                 dids, evals, fitness, lineage, on_iter):
        """Re-emit the eager loop's per-iteration side effects (telemetry
        rows, fitness-window appends, the evolve bookkeeping, ``on_iter``)
        from one fused epoch's stacked device outputs."""
        # per-iteration metric slices exist only for the telemetry rows /
        # the on_iter hook; with neither attached, skip the dispatch of
        # epoch_len x len(metrics) slice ops entirely
        emit = self.telemetry.enabled or on_iter is not None
        for i in range(epoch_len):
            metrics = stats = None
            if emit:
                metrics = jax.tree.map(lambda x: x[i], m_stack)
                stats = jax.tree.map(lambda x: x[i], s_stack)
            fit_i = None
            if n_evals and (i + 1) % eval_every == 0:
                fit_i = evals[(i + 1) // eval_every - 1]
                if not evolving:
                    self.report_fitness(fit_i)
                self.telemetry.record_members(base + i + 1, fitness=fit_i,
                                              hypers=hypers_before)
            lin_i = None
            if evolving and i == epoch_len - 1:
                # the evolve ran on device at the end of the epoch; surface
                # it through the same telemetry rows as the eager path
                if strat_state is not None:
                    self.strategy.import_state(strat_state)
                self.hypers = new_hypers
                self.last_fitness = fitness
                self._window.clear()
                lin_i = lineage
                self.telemetry.record_evolve(
                    base + epoch_len, lineage, fitness=fitness,
                    strategy=type(self.strategy).__name__)
                if self.telemetry.enabled:
                    self.telemetry.record_members(base + epoch_len,
                                                  hypers=self.hypers)
            if emit:
                self.telemetry.record_iteration(base + i, metrics=metrics,
                                                stats=stats,
                                                did_update=dids[i])
            if on_iter is not None:
                on_iter(base + i - start, metrics, stats, fit_i, lin_i)

    # ---------------------------------------------------------------- evolve
    def report_fitness(self, fitness):
        """Feed externally-measured per-member fitness (episode returns)
        into the window — for loops where evaluation happens outside
        ``step`` (e.g. CEM's evaluate-after-training ordering).

        Rows stay ON DEVICE: the window only ever feeds the (jitted) evolve
        and the telemetry/checkpoint sinks, so forcing a host sync here —
        the old ``np.asarray`` — stalled every evaluation iteration for a
        value nothing on the host path reads (``tests/test_fused_epoch.py``
        pins the warm loop host-transfer-free)."""
        self._window.append(jnp.asarray(fitness))

    def fitness(self):
        """Windowed-mean per-member fitness, shape (N,) — a device value."""
        if not self._window:
            return None
        return jnp.mean(jnp.stack(list(self._window)), axis=0)

    def _maybe_evolve(self):
        """Evolve iff on cadence (every ``pcfg.pbt_interval`` trainer steps,
        non-null strategy, non-empty fitness window); the single predicate
        shared by ``step`` and ``run_env_loop``."""
        if (not self.strategy.null and self.pcfg.pbt_interval
                and self.step_count % self.pcfg.pbt_interval == 0
                and self._window):
            return self.evolve()
        return None

    def evolve(self):
        self.last_fitness = self.fitness()
        self.key, k = jax.random.split(self.key)
        with self.telemetry.phase("evolve"), \
                self.telemetry.compile_scope("evolve"):
            # the strategy's executable compiles on the FIRST evolve (after
            # warmup flipped to "steady"); label it so steady-state compile
            # counts stay an honest recompile alarm
            self.state, self.hypers, lineage = self.strategy.evolve(
                k, self.state, self.hypers, jnp.asarray(self.last_fitness))
        # pre-evolve fitness describes states that may just have been
        # replaced; start the next window fresh
        self._window.clear()
        self.telemetry.record_evolve(self.step_count, lineage,
                                     fitness=self.last_fitness,
                                     strategy=type(self.strategy).__name__)
        if self.telemetry.enabled:
            # post-evolve snapshot: the hypers the children will train with
            self.telemetry.record_members(self.step_count,
                                          hypers=self.hypers)
        return lineage

    # ------------------------------------------------------------ checkpoint
    @property
    def actors(self):
        """Stacked per-member policy params (for rollout / serving)."""
        return self.agent.actor_params(self.state)

    def save(self, extra: dict | None = None, *, blocking: bool = False):
        """Checkpoint the full elastic-resumable state: the main tree
        (population state + strategy internals), the stacked actor params
        plus hypers and the attached rollout engine's replay buffers/env
        states as aux trees, and — in the JSON extras — the population
        size and current fitness, so ``repro.elastic.restore_elastic`` can
        resize by fitness when the next run has a different device count
        or population, and ``repro.serve.ContinuousEvaluator`` can promote
        serving members from the actors aux without a trainer restore.

        Only the live fitness window is recorded: ``last_fitness``
        describes pre-evolve states that may just have been replaced
        (CEM/DvD redraw members wholesale), so right after an evolve the
        checkpoint carries no fitness and an elastic resize falls back to
        by-index selection, loudly."""
        if self._mgr is None:
            raise ValueError("PopTrainer built without checkpoint_dir")
        fit = self.fitness()
        meta = dict(extra or {}, size=self.n,
                    fitness=None if fit is None
                    else np.asarray(fit, dtype=np.float64).tolist())
        # hypers and the rollout engine state are aux trees with their own
        # templates, so a restoring trainer that lacks either (a null
        # strategy after an elastic shrink to size 1; no attached rollout)
        # can still restore the main tree; "actors" duplicates the policy
        # slice of the main tree so ``repro.serve`` can promote members
        # from a live checkpoint against an agent-derived template — no
        # optimizer/strategy/buffer restore on the serving side (the few
        # extra actor bytes are noise next to the replay buffers)
        aux = {"actors": self.actors}
        if self.hypers is not None:
            aux["hypers"] = self.hypers
        if self._rollout is not None:
            aux["rollout"] = self._rollout.export_state()
        save = self._mgr.save if blocking else self._mgr.save_async
        t0 = time.perf_counter()
        with self.telemetry.phase("ckpt"):
            save(self.step_count - 1,
                 (self.state, self.strategy.export_state()), meta, aux=aux)
        self.telemetry.record_ckpt(self.step_count - 1,
                                   time.perf_counter() - t0,
                                   blocking=blocking)

    def resume(self):
        """Restore the latest checkpoint if one exists (population state,
        hypers, strategy internals, rollout buffers/env states when an
        engine is attached, step); returns the restored step (the value
        saved by ``save``) or None.  Same-topology resume only — resuming
        onto a different population size or device count goes through
        ``repro.elastic.restore_elastic``."""
        if self._mgr is None or self._mgr.latest() is None:
            return None
        (state, strat_state), extra = self._mgr.restore(
            (self.state, self.strategy.export_state()))
        restored_n = jax.tree.leaves(self.agent.actor_params(state))[0].shape[0]
        if restored_n != self.n:
            raise ValueError(
                f"checkpoint holds a population of {restored_n} but the "
                f"config says size={self.n}; resume with the original size, "
                f"or resize explicitly via repro.elastic.restore_elastic "
                f"(launch.train: --resize auto)")
        # restored leaves are host numpy: re-establish the same placement
        # __init__ gave the fresh state (islands layout / sharded mesh)
        place = self._placement()
        self.state = place(state)
        if self.hypers is not None:
            hypers = self._mgr.restore_aux("hypers", self.hypers)
            if hypers is not None:
                self.hypers = place(hypers)
        if strat_state is not None:
            self.strategy.import_state(strat_state)
        if self._rollout is not None:
            rstate = self._mgr.restore_aux(
                "rollout", self._rollout.export_state())
            if rstate is not None:
                self._rollout.import_state(rstate)
        self.step_count = extra["step"] + 1
        return extra["step"]

    def _placement(self):
        """How this trainer places a restored host pytree: the islands
        layout, the sharded-backend mesh, or plain default-device put —
        the same choice ``__init__`` made for the fresh state (and that
        ``repro.elastic.restore_elastic`` reuses)."""
        if self.layout is not None:
            return self.layout.place
        if self.mesh is not None:
            from repro.core.distributed import shard_population
            return lambda tree: shard_population(tree, self.mesh)
        return jax.device_put

    def wait(self):
        if self._mgr is not None:
            self._mgr.wait()
