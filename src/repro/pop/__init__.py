# Unified population-training API (the paper's thesis as an interface):
# single-agent training is population training with size=1, and every
# evolution strategy / update backend is a config string, not a call site.
from repro.pop.agent import (  # noqa: F401
    Agent, ModuleAgent, PPOAgent, LMAgent, SharedCriticAgent,
)
from repro.pop.strategy import (  # noqa: F401
    EvolutionStrategy, NoEvolution, PBT, CEM, DvD,
    STRATEGIES, make_strategy, register_strategy,
)
from repro.pop.backend import (  # noqa: F401
    UpdateBackend, BACKENDS, make_update, register_backend,
)
from repro.pop.trainer import PopTrainer  # noqa: F401
