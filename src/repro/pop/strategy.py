"""``EvolutionStrategy``: one signature for every outer loop.

The repo's three evolution mechanisms — PBT exploit/explore (Jaderberg et
al. 2017), CEM distribution refitting (CEM-RL, Pourchot & Sigaud 2019) and
DvD determinant diversity (Parker-Holder et al. 2020) — plus the null
strategy all answer the same call:

    evolve(key, pop_state, hypers, fitness) -> (pop_state, hypers, lineage)

``lineage`` is an (N,) int array: ``lineage[i]`` is the member whose state
member i now holds (``i`` for survivors, ``-1`` for members freshly drawn
from a search distribution).  ``NoEvolution`` is the null object that makes
population size 1 the degenerate case — no ``if n == 1`` branches anywhere.

Strategies are driver-level objects (constructed once per run, invoked every
``pbt_interval`` trainer steps), so distribution state (CEM's gaussian) may
live on the instance rather than being threaded through jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.base import PopulationConfig
from repro.core.cem import cem_init, cem_sample, cem_update
from repro.core.dvd import dvd_coef_schedule
from repro.core.hyperparams import sample_hypers
from repro.core.pbt import pbt_step


class EvolutionStrategy:
    """Base class / protocol. Subclasses override ``evolve_fn``.

    ``evolve_fn()`` returns the PURE evolve step

        fn(key, pop_state, hypers, fitness, strat_state)
            -> (pop_state, hypers, lineage, strat_state)

    with every input/output a jax value (or None), so the rollout engine can
    fuse it into the jitted train–evolve epoch; ``strat_state`` threads the
    strategy's internal distribution state (CEM's gaussian) through jit
    instead of mutating the instance.  ``evolve`` is the eager driver-level
    wrapper: it feeds ``export_state()`` in, applies ``import_state`` to
    what comes out, and keeps the historical 3-tuple signature.
    """

    null = False  # True: trainer skips the evolve step entirely

    def init_hypers(self, key, n: int):
        """Per-member dynamic hyperparameters, or None."""
        return None

    def configure_agent(self, agent):
        """Hook run before the update fn is built (e.g. DvD installs its
        diversity-coefficient schedule on a shared-critic agent)."""

    def bind(self, key, agent, pop_state):
        """Hook run once at trainer init; may transform the initial
        population (e.g. CEM draws members from its distribution)."""
        return pop_state

    # Internal strategy state (CEM's gaussian) must survive checkpoint /
    # resume alongside the population itself.
    def export_state(self):
        return None

    def import_state(self, state):
        """Restore what ``export_state`` produced (no-op by default)."""

    def evolve_fn(self):
        raise NotImplementedError

    def evolve_jit(self):
        """``jax.jit(evolve_fn())``, cached — the ONE compiled evolve step
        both the eager ``evolve`` wrapper and the fused train–evolve epoch
        call, so the two paths share one executable (and therefore one set
        of float-rounding decisions: the epoch parity tests compare them
        bitwise)."""
        fn = getattr(self, "_evolve_jit", None)
        if fn is None:
            fn = self._evolve_jit = jax.jit(self.evolve_fn())
        return fn

    def evolve(self, key, pop_state, hypers, fitness):
        pop_state, hypers, lineage, strat_state = self.evolve_jit()(
            key, pop_state, hypers, fitness, self.export_state())
        if strat_state is not None:
            self.import_state(strat_state)
        return pop_state, hypers, lineage


def _identity_evolve(key, pop_state, hypers, fitness, strat_state):
    return pop_state, hypers, jnp.arange(fitness.shape[0]), strat_state


class NoEvolution(EvolutionStrategy):
    """Population size 1 — or any run without an outer loop."""

    null = True

    def __init__(self, pcfg: PopulationConfig | None = None):
        self.pcfg = pcfg

    def evolve_fn(self):
        return _identity_evolve


class PBT(EvolutionStrategy):
    """Truncation-selection PBT over training state + hyperparameters."""

    def __init__(self, pcfg: PopulationConfig):
        self.pcfg = pcfg
        self._gather = None

    def init_hypers(self, key, n: int):
        space = self.pcfg.hyper_space
        if not space.names:
            return None
        return sample_hypers(key, space, n)

    def bind(self, key, agent, pop_state):
        self._gather = agent.gather_members
        return pop_state

    def evolve_fn(self):
        pcfg, gather = self.pcfg, self._gather

        def fn(key, pop_state, hypers, fitness, strat_state):
            state, new_hypers, parents = pbt_step(
                key, pop_state, {} if hypers is None else hypers, fitness,
                pcfg, gather=gather)
            return (state, (None if hypers is None else new_hypers),
                    parents, strat_state)

        return fn


class CEM(EvolutionStrategy):
    """Diagonal-gaussian CEM over the agent's evolvable (policy) params.

    ``bind`` centres the distribution on member 0 and redraws the initial
    population from it; ``evolve`` refits on the elites and resamples every
    member (lineage is all -1: nobody inherits a specific parent's state).
    """

    def __init__(self, pcfg: PopulationConfig):
        self.pcfg = pcfg
        self._agent = None
        self.cem_state = None
        self._unravel = None

    def bind(self, key, agent, pop_state):
        self._agent = agent
        template = jax.tree.map(lambda x: x[0],
                                agent.evolvable_params(pop_state))
        self.cem_state, self._unravel = cem_init(
            template, sigma_init=self.pcfg.sigma_init,
            noise_init=self.pcfg.cem_noise_init)
        n = jax.tree.leaves(pop_state)[0].shape[0]
        return self._inject(key, pop_state, n)

    def _inject(self, key, pop_state, n: int):
        flat = cem_sample(key, self.cem_state, n)
        new_params = jax.vmap(self._unravel)(flat)
        return self._agent.with_evolvable_params(pop_state, new_params)

    def export_state(self):
        return self.cem_state

    def import_state(self, state):
        from repro.core.cem import CEMState
        self.cem_state = CEMState(*state)

    def evolve_fn(self):
        agent, unravel, pcfg = self._agent, self._unravel, self.pcfg

        def fn(key, pop_state, hypers, fitness, strat_state):
            n = fitness.shape[0]
            flat = jax.vmap(lambda p: ravel_pytree(p)[0])(
                agent.evolvable_params(pop_state))
            cs = cem_update(strat_state, flat, fitness,
                            elite_frac=pcfg.elite_frac,
                            noise_decay=pcfg.cem_noise_decay)
            new_params = jax.vmap(unravel)(cem_sample(key, cs, n))
            pop_state = agent.with_evolvable_params(pop_state, new_params)
            return pop_state, hypers, jnp.full((n,), -1, jnp.int32), cs

        return fn


class DvD(EvolutionStrategy):
    """Diversity via Determinants: selection pressure is replaced by the
    -logdet kernel term inside the update loss, so ``evolve`` is the
    identity; ``configure_agent`` installs the §B.2 coefficient schedule on
    agents that support it (the shared-critic family)."""

    def __init__(self, pcfg: PopulationConfig):
        self.pcfg = pcfg

    def configure_agent(self, agent):
        if hasattr(agent, "dvd_coef_fn") and agent.dvd_coef_fn is None:
            period = self.pcfg.dvd_period
            agent.dvd_coef_fn = lambda step: dvd_coef_schedule(
                step, period=period)

    def evolve_fn(self):
        return _identity_evolve


STRATEGIES: dict[str, type] = {
    "none": NoEvolution,
    "pbt": PBT,
    "cem": CEM,
    "dvd": DvD,
}


def register_strategy(name: str, cls: type):
    STRATEGIES[name] = cls


def make_strategy(pcfg: PopulationConfig) -> EvolutionStrategy:
    """Resolve ``pcfg.strategy``; size 1 is always the null strategy (the
    degenerate case the unified API promises)."""
    if pcfg.size <= 1:
        return NoEvolution(pcfg)
    name = pcfg.strategy
    if isinstance(name, EvolutionStrategy):
        return name
    try:
        return STRATEGIES[name](pcfg)
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"registered: {sorted(STRATEGIES)}") from None
