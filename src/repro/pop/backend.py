"""``UpdateBackend``: how the population update executes, as a config value.

Wraps the paper's compilation protocols (``repro.core.vectorize``) and the
mesh distribution layer (``repro.core.distributed``) behind one registry, so
"vectorized vs sequential vs sharded" is a string in the config rather than
a different call site:

  * ``vectorized`` — jit(vmap(step)), the paper's protocol (Fig. 1 right);
                     ``num_steps`` chains updates via lax.scan and
                     ``donate`` donates the population buffers.
  * ``sequential`` — the paper's *Jax (Sequential)* baseline: one jit'd
                     single-agent step looped over members.
  * ``sharded``    — vectorized, with the population axis sharded over the
                     device mesh by GSPMD; the trainer places the state via
                     ``distributed.shard_population``.
  * ``islands``    — member groups shard_mapped over the ``"pop"`` axis of
                     an ``repro.elastic.IslandLayout`` (the paper's §5.1
                     islands-per-accelerator topology made explicit);
                     registered by ``repro.elastic.islands``, resolved
                     lazily on first use.

For ``population_level`` agents (shared critic, §4.2) the same names map to
the paper's averaged-loss update (vectorized) vs the original CEM-RL
interleaved ordering (sequential).

Builders are ``builder(agent, num_steps, donate)``; a builder that also
accepts a ``mesh`` keyword (the islands backend) receives the trainer's
mesh through ``make_update(..., mesh=...)``.
"""
from __future__ import annotations

import inspect
from enum import Enum

import jax


class UpdateBackend(str, Enum):
    VECTORIZED = "vectorized"
    SEQUENTIAL = "sequential"
    SHARDED = "sharded"


def _build_vectorized(agent, num_steps: int, donate: bool):
    from repro.core.vectorize import chain_steps, vectorized_update
    if agent.population_level:
        return jax.jit(agent.population_update())
    if getattr(agent, "fused_adam", False):
        fn = agent.fused_update()
        if fn is not None:
            # population-level update (optimizer hoisted into
            # repro.optim.population_adam); batches keep the same
            # (num_steps, N, B, ...) layout, chained at population level
            inner = fn if num_steps == 1 else chain_steps(fn, num_steps)
            return jax.jit(inner, donate_argnums=(0,) if donate else ())
    return vectorized_update(agent.update, num_steps=num_steps, donate=donate)


def _build_sequential(agent, num_steps: int, donate: bool):
    from repro.core.vectorize import sequential_update
    if agent.population_level:
        return jax.jit(agent.population_update(sequential=True))
    return sequential_update(agent.update, num_steps=num_steps)


def _build_sharded(agent, num_steps: int, donate: bool):
    if agent.population_level:
        raise ValueError("sharded backend requires per-member agents "
                         "(the shared critic is replicated, not sharded)")
    return _build_vectorized(agent, num_steps, donate)


BACKENDS = {
    UpdateBackend.VECTORIZED: _build_vectorized,
    UpdateBackend.SEQUENTIAL: _build_sequential,
    UpdateBackend.SHARDED: _build_sharded,
}


def register_backend(name: str, builder):
    try:
        name = UpdateBackend(name)
    except ValueError:
        pass
    BACKENDS[name] = builder


def make_update(agent, backend="vectorized", *, num_steps: int = 1,
                donate: bool = True, mesh=None):
    """Build ``fn(pop_state, batches, hypers) -> (pop_state, metrics)``.

    batches: leaves (N, ...) when num_steps == 1, else (num_steps, N, ...)
    (per-member agents); population-level agents always take (N, B, ...).
    ``mesh`` is forwarded to builders that accept it (islands backend).
    """
    try:
        key = UpdateBackend(backend)
    except ValueError:
        key = backend
    builder = BACKENDS.get(key)
    if builder is None and key == "islands":
        import repro.elastic  # noqa: F401  registers the islands backend
        builder = BACKENDS.get(key)
    if builder is None:
        names = sorted(b.value if isinstance(b, UpdateBackend) else str(b)
                       for b in BACKENDS)
        raise ValueError(f"unknown backend {backend!r}; registered: {names}")
    if "mesh" in inspect.signature(builder).parameters:
        return builder(agent, num_steps, donate, mesh=mesh)
    return builder(agent, num_steps, donate)
