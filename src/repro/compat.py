"""Version shims for the jax APIs this repo uses that moved across releases.

Two surfaces differ between the jax the image ships (0.4.x) and current
releases (>= 0.5):

  * ``jax.sharding.get_abstract_mesh`` — the public accessor for the
    ambient abstract mesh does not exist on 0.4.x (the private
    ``jax._src.mesh.get_abstract_mesh`` returns a different type there).
    On old jax we report "no mesh context": sharding constraints become
    no-ops, which is the correct degenerate behaviour on a single device.
  * ``jax.sharding.AxisType`` / the ``axis_types=`` kwarg of
    ``jax.make_mesh`` — absent on 0.4.x, where all axes are Auto anyway.

Everything in the repo goes through these two helpers instead of touching
``jax.sharding`` directly for mesh construction / mesh-context queries.
"""
from __future__ import annotations

import inspect

import jax


def get_abstract_mesh():
    """The ambient abstract mesh, or None when unset / unsupported."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    return fn()


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient: ``jax.sharding.set_mesh`` on
    new jax; on 0.4.x the Mesh object itself is the context manager."""
    fn = getattr(jax.sharding, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` where it exists, else the 0.4.x experimental one.
    The replication-check kwarg was renamed (check_rep -> check_vma) partway
    through, so pick whichever the installed signature accepts."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    kw = {}
    if "check_vma" in params:
        kw["check_vma"] = False
    elif "check_rep" in params:
        kw["check_rep"] = False
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` with Auto (or Explicit) axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    kind = axis_type.Explicit if explicit else axis_type.Auto
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(kind,) * len(axis_names))


def register_compile_listener(callback):
    """Invoke ``callback(event_name, seconds)`` for every XLA backend
    compilation in this process — the hook ``repro.telemetry`` uses to
    count and time recompiles (first-step warmup, elastic resizes, serving
    promotions of a new ensemble size).

    Rides ``jax.monitoring``'s duration events, filtering to the actual
    backend compile (ignoring the trace/lowering sub-events, which fire
    per jaxpr and would triple-count).  Returns an *unregister* callable,
    or None when this jax has no monitoring surface — callers treat
    compile telemetry as best-effort either way.  Unregistration goes
    through the private ``jax._src.monitoring`` API when the public one
    (newer jax) is absent; failure to unregister leaves a listener whose
    callback is a no-op after ``RunTelemetry.close``, which is harmless.
    """
    try:
        from jax import monitoring
    except ImportError:
        return None
    if not hasattr(monitoring, "register_event_duration_secs_listener"):
        return None

    def _listener(event, duration, **kwargs):
        if event.endswith("backend_compile_duration"):
            callback(event, duration)

    monitoring.register_event_duration_secs_listener(_listener)

    def _unregister():
        try:
            from jax._src import monitoring as _mi
            _mi._unregister_event_duration_listener_by_callback(_listener)
        except Exception:
            pass

    return _unregister


def enable_compilation_cache(path) -> bool:
    """Point jax's persistent compilation cache at ``path`` (created if
    missing), so a process restart reuses yesterday's XLA executables
    instead of recompiling — the production-restart half of the paper's
    compilation-cost protocol (``benchmarks/compile_time.py`` pins the
    win; the resize cycle in ``benchmarks/elastic_resize.py`` is
    compile-dominated, which is exactly what this amortizes).

    The knobs moved across releases: the dir config is stable, but the
    min-compile-time / min-entry-size thresholds (which default to
    skipping the small CPU executables this repo compiles) appeared later
    — each is applied best-effort.  Returns True when the cache dir was
    accepted, False when this jax has no persistent cache at all.
    """
    import os

    os.makedirs(str(path), exist_ok=True)
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
    except AttributeError:
        return False
    for knob, value in (("jax_persistent_cache_min_compile_time_secs", 0),
                        ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, value)
        except AttributeError:
            pass
    return True
