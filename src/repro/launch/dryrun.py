import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for every cell,
and the compiled artifact yields memory_analysis / cost_analysis / the HLO
text that feeds the roofline pass (repro.launch.hlo_analysis).

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (LM_SHAPES, LMConfig, TrainConfig, applicable_shapes,
                           get_config)
from repro.configs.registry import _ARCHS
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import lm as lm_mod
from repro.models.sharding import batch_spec, param_specs
from repro import compat


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _replicated_like(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _batch_shardings(mesh, batch):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, batch_spec(s.shape, mesh)), batch)


def _decode_state_shardings(cfg, shape, mesh):
    """Shard KV caches / SSM states: batch dim -> ('pod','data') when it
    divides, cache sequence dim -> 'model' (flash-decoding layout)."""
    from repro.models.sharding import fsdp_axes, _axis_size
    shapes = lm_mod.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    dp = fsdp_axes(mesh)
    model_size = mesh.shape.get("model", 1)

    # prefer sharding the kv-head / ssm-head dim over 'model' when it
    # divides: a cache write (dynamic_update_slice at the decode index) on a
    # model-sharded SEQUENCE axis lowers to collective-permute chains
    # (measured: 4k+ permutes on zamba long_500k); head-sharded caches keep
    # writes local.
    head_dims = {cfg.num_kv_heads}
    if cfg.block_type == "mamba2":
        head_dims.add(2 * cfg.d_model // cfg.ssm_head_dim)   # ssm heads
    if cfg.block_type == "rwkv6":
        head_dims.add(cfg.d_model // cfg.ssm_head_dim)       # rwkv heads
    head_dims = {d for d in head_dims
                 if d % model_size == 0 and
                 d not in (shape.seq_len, shape.global_batch)}

    def spec(leaf):
        # never consider the leading stacked-layer axis as a head dim
        inner = leaf.shape[1:]
        shardable_head = any(d in head_dims for d in inner)
        used_model = False
        dims = [None]  # stacked-layer axis stays unsharded
        for d in inner:
            if d == shape.global_batch and dp is not None and \
                    d % _axis_size(mesh, dp) == 0 and shape.global_batch > 1:
                dims.append(dp)
            elif shardable_head and not used_model and d in head_dims:
                dims.append("model")
                used_model = True
            elif d == shape.seq_len and d % model_size == 0 \
                    and not shardable_head and not used_model:
                dims.append("model")
                used_model = True
            else:
                dims.append(None)
        # never shard two dims on the same axis
        seen, out = set(), []
        for a in dims:
            key = tuple(a) if isinstance(a, tuple) else a
            if key is not None and key in seen:
                out.append(None)
            else:
                out.append(a)
                if key is not None:
                    seen.add(key)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(spec, shapes), shapes


def build_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None, mesh=None):
    """Lower + compile one cell. Returns (compiled, lowered, info dict)."""
    cfg: LMConfig = cfg_override or get_config(arch)
    shape = LM_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        raise ValueError(f"{arch} is pure full-attention; long_500k skipped "
                         f"by design (DESIGN.md §Arch-applicability)")
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)

    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(lambda k: lm_mod.init_params(k, cfg), key)
    param_sh = _named(mesh, param_specs(params_struct, mesh))
    batch = lm_mod.input_specs(cfg, shape)
    batch_sh = _batch_shardings(mesh, batch)

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            tcfg = TrainConfig()
            opt_init, train_step = lm_mod.make_train_step(cfg, tcfg)
            opt_struct = jax.eval_shape(opt_init, params_struct)
            from repro.optim.optimizers import AdamState
            opt_sh = AdamState(step=NamedSharding(mesh, P()),
                               mu=param_sh, nu=param_sh)
            step_struct = jax.ShapeDtypeStruct((), jnp.int32)
            out_struct = jax.eval_shape(train_step, params_struct, opt_struct,
                                        batch, step_struct)
            out_sh = (param_sh, opt_sh, _replicated_like(mesh, out_struct[2]))
            fn = jax.jit(lambda p, o, b, s: train_step(p, o, b, s),
                         in_shardings=(param_sh, opt_sh, batch_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=out_sh,
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_struct, opt_struct, batch, step_struct)
        elif shape.kind == "prefill":
            def prefill(p, b):
                logits, _, _ = lm_mod.forward(p, cfg, b)
                return logits
            fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
            lowered = fn.lower(params_struct, batch)
        else:  # decode
            serve = lm_mod.make_serve_step(cfg)
            state_sh, state_struct = _decode_state_shardings(cfg, shape, mesh)
            idx_struct = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(serve,
                         in_shardings=(param_sh, batch_sh, state_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(None, state_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_struct, batch, state_struct, idx_struct)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per computation
        cost = cost[0] if cost else None
    info = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": dict(mesh.shape), "num_devices": mesh.devices.size,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": {
            "arguments": getattr(mem, "argument_size_in_bytes", None),
            "outputs": getattr(mem, "output_size_in_bytes", None),
            "temps": getattr(mem, "temp_size_in_bytes", None),
            "aliased": getattr(mem, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis_flops": cost.get("flops") if cost else None,
    }
    return compiled, lowered, info


class _TPOnlyMesh:
    """Mesh view exposing only the 'model' axis to the param-spec rules:
    in population mode the ('pod','data') axes hold population members, so
    member-internal sharding is TP-only."""

    def __init__(self, mesh):
        self._mesh = mesh
        self.axis_names = ("model",)
        self.shape = {"model": mesh.shape["model"]}


def build_population_cell(arch: str, shape_name: str, n: int, *,
                          multi_pod: bool = False, mesh=None,
                          cfg_override=None):
    """Lower + compile the PAPER'S protocol at LM scale: one jit'd vmapped
    train step updating n population members, members sharded over the
    ('pod','data') mesh axes, each member TP-sharded over 'model'.  The
    global token budget of the shape is split across members (fair
    comparison against the n=1 cell)."""
    cfg: LMConfig = cfg_override or get_config(arch)
    shape = LM_SHAPES[shape_name]
    assert shape.kind == "train", "population dry-run targets train shapes"
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    from repro.models.sharding import fsdp_axes
    pop_axes = fsdp_axes(mesh)

    key = jax.random.PRNGKey(0)
    member_struct = jax.eval_shape(lambda k: lm_mod.init_params(k, cfg), key)
    pop_struct = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), member_struct)
    from repro.models.sharding import population_mode
    member_specs = param_specs(member_struct, _TPOnlyMesh(mesh))
    if "embed" in member_struct:
        # sharded-operand gathers with population-sharded indices trip an
        # XLA SPMD partitioner CHECK on CPU; replicate the member embedding
        # (it is small relative to a member's share of HBM).
        member_specs["embed"]["embedding"] = P(None, None)
    pop_specs = jax.tree.map(lambda sp: P(pop_axes, *sp), member_specs,
                             is_leaf=lambda x: isinstance(x, P))
    pop_sh = _named(mesh, pop_specs)

    per_member_batch = max(shape.global_batch // n, 1)
    batch = {"tokens": jax.ShapeDtypeStruct((n, per_member_batch,
                                             shape.seq_len), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.ShapeDtypeStruct(
            (n, per_member_batch, shape.seq_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (n, per_member_batch, cfg.num_frontend_positions, cfg.d_model),
            jnp.dtype(cfg.dtype))
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(pop_axes, *([None] * (len(s.shape) - 1)))),
        batch)

    tcfg = TrainConfig()
    opt_init, train_step = lm_mod.make_train_step(cfg, tcfg)
    opt_struct = jax.eval_shape(jax.vmap(opt_init), pop_struct)
    from repro.optim.optimizers import AdamState
    opt_sh = AdamState(step=NamedSharding(mesh, P(pop_axes)),
                       mu=pop_sh, nu=pop_sh)
    hyper_struct = {"lr_scale": jax.ShapeDtypeStruct((n,), jnp.float32)}
    hyper_sh = {"lr_scale": NamedSharding(mesh, P(pop_axes))}
    step_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def pop_step(params, opt, b, step, hypers):
        return jax.vmap(
            lambda p, o, bi, sc: train_step(p, o, bi, step, lr_scale=sc)
        )(params, opt, b, hypers["lr_scale"])

    with compat.set_mesh(mesh), population_mode():
        out_struct = jax.eval_shape(pop_step, pop_struct, opt_struct, batch,
                                    step_struct, hyper_struct)
        fn = jax.jit(pop_step,
                     in_shardings=(pop_sh, opt_sh, batch_sh,
                                   NamedSharding(mesh, P()), hyper_sh),
                     out_shardings=(pop_sh, opt_sh,
                                    _replicated_like(mesh, out_struct[2])),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pop_struct, opt_struct, batch, step_struct,
                           hyper_struct)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    info = {
        "arch": cfg.name, "shape": shape_name, "population": n,
        "mesh": dict(mesh.shape), "num_devices": mesh.devices.size,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": {
            "arguments": getattr(mem, "argument_size_in_bytes", None),
            "outputs": getattr(mem, "output_size_in_bytes", None),
            "temps": getattr(mem, "temp_size_in_bytes", None),
            "aliased": getattr(mem, "alias_size_in_bytes", None),
        },
    }
    return compiled, lowered, info


def analyze_cell(compiled, info) -> dict:
    hlo = compiled.as_text()
    a = analyze_hlo(hlo)
    terms = roofline_terms(a)
    info = dict(info)
    info.update({
        "hlo_flops_per_device": a["flops"],
        "hlo_traffic_bytes_per_device": a["traffic_bytes"],
        "collective_bytes_per_device": a["collective_bytes"],
        "collective_counts": a["collective_counts"],
        **{k: v for k, v in terms.items()},
    })
    return info


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             analyze: bool = True, mesh=None) -> dict:
    compiled, lowered, info = build_cell(arch, shape_name,
                                         multi_pod=multi_pod, mesh=mesh)
    if analyze:
        info = analyze_cell(compiled, info)
    return info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--population", type=int, default=0,
                    help="lower the paper's population-vectorized train step "
                         "for N members instead of the plain cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-analyze", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in _ARCHS:
            cfg = get_config(a)
            for s in applicable_shapes(cfg):
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                if args.population:
                    compiled, _, info = build_population_cell(
                        arch, shape, args.population, multi_pod=mp)
                    if not args.no_analyze:
                        info = analyze_cell(compiled, info)
                else:
                    info = run_cell(arch, shape, multi_pod=mp,
                                    analyze=not args.no_analyze)
                info["status"] = "ok"
                print(f"[dryrun] OK   {tag}: compile={info['compile_s']}s "
                      f"bottleneck={info.get('bottleneck')}", flush=True)
            except Exception as e:
                info = {"arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "error", "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
            results.append(info)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_bad = sum(r["status"] != "ok" for r in results)
    print(f"[dryrun] {len(results) - n_bad}/{len(results)} cells OK")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
