"""Production mesh construction (TPU v5e-256 pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 2, data: int | None = None, *,
                   pod: int | None = None):
    """Small mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    if pod:
        data = data or n // (model * pod)
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    data = data or max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
