"""Production mesh construction (TPU v5e-256 pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 2, data: int | None = None, *,
                   pod: int | None = None):
    """Small mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    if pod:
        data = data or n // (model * pod)
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    data = data or max(1, n // model)
    return compat.make_mesh((data, model), ("data", "model"))
