"""Batched serving driver: prefill + decode loop with a KV cache.

``python -m repro.launch.serve --arch qwen2-0.5b --smoke --tokens 32``
runs a batch of requests through one prefill pass and a jit'd decode loop
(one compiled step, reused every token — the inference analogue of the
paper's compilation protocol).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm as lm_mod


def generate(cfg, params, prompt_tokens, *, steps: int, max_len: int,
             extra_inputs=None, greedy: bool = True, key=None):
    b, s0 = prompt_tokens.shape
    serve = jax.jit(lm_mod.make_serve_step(cfg))
    state = lm_mod.init_decode_state(cfg, b, max_len)

    # prefill token-by-token through the same compiled step (keeps one
    # executable; a chunked prefill kernel is the production variant)
    tok = prompt_tokens[:, :1]
    out = [tok]
    logits = None
    for t in range(s0 + steps - 1):
        batch = {"tokens": tok}
        if cfg.frontend == "audio_frames":
            batch["embeds"] = jnp.zeros((b, 1, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        logits, state = serve(params, batch, state, jnp.asarray(t, jnp.int32))
        if t + 1 < s0:
            tok = prompt_tokens[:, t + 1:t + 2]
        else:
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            else:
                key, ks = jax.random.split(key)
                tok = jax.random.categorical(ks, logits[:, -1])[:, None]
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = lm_mod.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(cfg, params, prompts, steps=args.tokens,
                   max_len=args.prompt_len + args.tokens + 1, key=key,
                   greedy=False)
    dt = time.time() - t0
    n_new = args.batch * args.tokens
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({1e3 * dt / n_new:.2f} ms/token)")
    print(out[:2])
    return out


if __name__ == "__main__":
    main()
