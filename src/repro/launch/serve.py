"""Serving driver: both inference workloads behind one CLI.

  * ``--arch <id>``   — LM batched decode: prefill + jit'd decode loop with
                        a KV cache (one compiled step reused every token —
                        the inference analogue of the paper's compilation
                        protocol).
  * ``--algo <name>`` — population-as-ensemble RL serving: load any
                        checkpoint ``launch/train.py`` produced, promote a
                        fitness+diversity serving set
                        (``repro.serve.ContinuousEvaluator``), and answer
                        batched observation requests through the
                        ``BatchServer``'s single jitted ensemble call —
                        continuously re-polling the checkpoint dir so a
                        still-training population keeps refreshing the
                        ensemble it serves.

``python -m repro.launch.serve --arch qwen2-0.5b --smoke --tokens 32``
``python -m repro.launch.serve --algo td3 --ckpt-dir /tmp/repro_ckpt``

``--compile-cache DIR`` points jax's persistent compilation cache at DIR
(shared with ``launch/train.py``) so serving restarts skip cold XLA
compiles — see ``benchmarks/compile_time.py`` for the measured win.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm as lm_mod
from repro.telemetry import make_telemetry


def generate(cfg, params, prompt_tokens, *, steps: int, max_len: int,
             extra_inputs=None, greedy: bool = True, key=None):
    b, s0 = prompt_tokens.shape
    serve = jax.jit(lm_mod.make_serve_step(cfg))
    state = lm_mod.init_decode_state(cfg, b, max_len)

    # prefill token-by-token through the same compiled step (keeps one
    # executable; a chunked prefill kernel is the production variant)
    tok = prompt_tokens[:, :1]
    out = [tok]
    logits = None
    for t in range(s0 + steps - 1):
        batch = {"tokens": tok}
        if cfg.frontend == "audio_frames":
            batch["embeds"] = jnp.zeros((b, 1, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        logits, state = serve(params, batch, state, jnp.asarray(t, jnp.int32))
        if t + 1 < s0:
            tok = prompt_tokens[:, t + 1:t + 2]
        else:
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            else:
                key, ks = jax.random.split(key)
                tok = jax.random.categorical(ks, logits[:, -1])[:, None]
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def _serve_rl(args):
    """RL branch: ensemble inference over a trained population.

    Requests are synthesized from env resets (the env is the traffic
    model this box has); a real frontend swaps :func:`_request_batch` for
    its socket and keeps everything else.
    """
    from repro.checkpoint import CheckpointManager
    from repro.envs import make
    from repro.rl import make_agent
    from repro.serve import (BatchServer, ContinuousEvaluator, PolicyForward,
                             probe_observations)

    env = make(args.env)
    agent = make_agent(args.algo, env.spec)
    # --fused-linear: the ensemble call evaluates all members through the
    # population-batched forward (kernels/pop_matmul layout) instead of
    # vmap of the per-member apply — same actions, one kernel on TPU
    forward = PolicyForward.fused_for_agent(agent) if args.fused_linear \
        else None
    telemetry = make_telemetry(
        args.log_dir, console=False,
        meta={"workload": "serve-rl", "algo": args.algo, "env": args.env,
              "mode": args.mode, "ensemble": args.ensemble,
              "batch": args.batch})
    mgr = CheckpointManager(args.ckpt_dir)
    if mgr.latest() is None:
        raise FileNotFoundError(
            f"no checkpoint in {args.ckpt_dir}; train one first: "
            f"python -m repro.launch.train --algo {args.algo} "
            f"--env {args.env} --ckpt-dir {args.ckpt_dir}")

    key = jax.random.PRNGKey(args.seed)
    key, kp = jax.random.split(key)
    watcher = ContinuousEvaluator(
        mgr, agent, size=args.ensemble,
        probe_obs=probe_observations(env, kp, args.probe),
        diversity_weight=args.diversity_weight, forward=forward,
        telemetry=telemetry)
    sset = watcher.poll()

    mesh = None
    if args.islands:
        from repro.elastic import plan_layout
        mesh = plan_layout(len(jax.devices()), sset.size).mesh
        print(f"[serve] islands mesh over {len(jax.devices())} devices")
    server = BatchServer(watcher.forward, env.spec, sset,
                         max_batch=args.batch, mode=args.mode, mesh=mesh,
                         telemetry=telemetry,
                         telemetry_every=args.telemetry_every)
    print(f"[serve] algo={args.algo} env={args.env} mode={args.mode} "
          f"batch={args.batch} {sset.describe()}")

    def _request_batch(k):
        _, obs = jax.vmap(env.reset)(jax.random.split(k, args.batch))
        return np.asarray(obs)

    # warm-up compiles the ensemble executable outside the timed loop
    server.warmup()
    server.serve(_request_batch(key))

    lat = []
    t0 = time.time()
    for i in range(args.requests):
        telemetry.tick_profile(i, args.profile, iters=args.profile_iters)
        key, kr = jax.random.split(key)
        obs = _request_batch(kr)
        t1 = time.perf_counter()
        actions = server.serve(obs)
        lat.append(time.perf_counter() - t1)
        if args.poll_every and (i + 1) % args.poll_every == 0:
            # a promotion of a new ensemble SIZE recompiles the serving
            # executable once — attribute those compile rows to it
            with telemetry.compile_scope("promotion"):
                newer = watcher.poll(server)
            if newer is not None:
                ev = watcher.events[-1]
                print(f"[serve] promoted step {newer.step}: "
                      f"+{ev['promoted']} -{ev['demoted']}")
    dt = time.time() - t0
    served = args.requests * args.batch
    lat_ms = 1e3 * np.asarray(lat)
    print(f"[serve] {served} requests in {dt:.2f}s "
          f"({served / dt:.0f} req/s, p50 {np.percentile(lat_ms, 50):.2f} ms"
          f" p99 {np.percentile(lat_ms, 99):.2f} ms per batch)")
    print(f"[serve] last actions[:2] = {np.asarray(actions)[:2]}")
    server.report_telemetry()            # flush the partial tail window
    telemetry.record("run_end", requests=served, secs=round(dt, 4),
                     req_per_s=round(served / dt, 2),
                     compiles=telemetry.compile_count,
                     compile_secs=round(telemetry.compile_secs, 4))
    telemetry.close()
    return served / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM config id (decode workload; exclusive with "
                    "--algo)")
    ap.add_argument("--algo", default=None,
                    help="RL algorithm whose launch/train.py checkpoint to "
                    "serve as an ensemble (exclusive with --arch)")
    ap.add_argument("--env", default="pendulum",
                    help="pure-JAX env of the trained checkpoint")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt",
                    help="checkpoint dir written by launch/train.py")
    ap.add_argument("--ensemble", type=int, default=4,
                    help="serving-set size (fitness + DvD selection)")
    ap.add_argument("--mode", default="mean",
                    choices=["mean", "vote", "best"],
                    help="ensemble reduction")
    ap.add_argument("--requests", type=int, default=64,
                    help="request batches to serve in the demo loop")
    ap.add_argument("--poll-every", type=int, default=16,
                    help="re-poll the checkpoint dir every N batches "
                    "(0 = never): continuous promotion")
    ap.add_argument("--probe", type=int, default=32,
                    help="probe observations for behavioral embeddings")
    ap.add_argument("--diversity-weight", type=float, default=1.0)
    ap.add_argument("--fused-linear", action="store_true",
                    help="serve the ensemble through the population-"
                    "batched forward (kernels/pop_matmul on TPU) instead "
                    "of vmap over members")
    ap.add_argument("--islands", action="store_true",
                    help="shard the ensemble's member axis over all "
                    "devices (populations too big for one accelerator)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent jax compilation cache directory "
                    "(share it with launch/train.py)")
    ap.add_argument("--log-dir", default=None, metavar="DIR",
                    help="write structured telemetry (latency histogram, "
                    "promotion audit trail, compile events) to "
                    "DIR/telemetry.jsonl; inspect with tools/report.py")
    ap.add_argument("--telemetry-every", type=int, default=16,
                    help="summarize the serving latency window into one "
                    "telemetry row every N served batches")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of a few steady-"
                    "state request batches into DIR")
    ap.add_argument("--profile-iters", type=int, default=3,
                    help="request batches to keep the profiler trace open")
    args = ap.parse_args(argv)

    if (args.arch is None) == (args.algo is None):
        ap.error("pass exactly one of --arch (LM) or --algo (RL ensemble)")
    if args.compile_cache:
        from repro import compat
        compat.enable_compilation_cache(args.compile_cache)
    if args.algo is not None:
        return _serve_rl(args)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    telemetry = make_telemetry(
        args.log_dir, console=False,
        meta={"workload": "serve-lm", "arch": cfg.name,
              "batch": args.batch, "tokens": args.tokens})
    key = jax.random.PRNGKey(args.seed)
    params = lm_mod.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    if args.profile:
        telemetry.start_profile(args.profile)
    t0 = time.time()
    out = generate(cfg, params, prompts, steps=args.tokens,
                   max_len=args.prompt_len + args.tokens + 1, key=key,
                   greedy=False)
    dt = time.time() - t0
    telemetry.stop_profile()
    n_new = args.batch * args.tokens
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({1e3 * dt / n_new:.2f} ms/token)")
    print(out[:2])
    telemetry.record("run_end", tokens=n_new, secs=round(dt, 4),
                     ms_per_token=round(1e3 * dt / n_new, 4),
                     compiles=telemetry.compile_count,
                     compile_secs=round(telemetry.compile_secs, 4))
    telemetry.close()
    return out


if __name__ == "__main__":
    main()
