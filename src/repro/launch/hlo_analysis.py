"""HLO-text analyzer for the roofline pass.

``compiled.cost_analysis()`` on XLA:CPU counts every while-loop body ONCE
(verified empirically — a 10-step scan of matmuls reports 1x the matmul
flops), so scan-over-layers models would be under-counted by ~num_layers.
This parser walks the post-SPMD optimized HLO text instead:

  * builds a per-computation symbol table (name -> shape/dtype),
  * resolves while-loop trip counts from the loop condition's
    ``compare(counter, constant(N))``,
  * attributes FLOPs (dot/convolution), memory traffic (operand+output bytes
    of non-fused ops), and collective bytes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute) to each computation,
  * rolls everything up through call sites (fusions excluded — a fusion op
    contributes its own operands/outputs, not its body's internals) with
    trip-count multipliers.

All shapes in the post-partitioning module are PER-DEVICE, so the returned
numbers are per-chip; the roofline terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# wire-bytes multiplier per output byte (ring-algorithm approximations)
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _parse_shapes(text):
    """All (dtype, dims) in a type string like '(bf16[2,3]{...}, f32[4]{..})'."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            out.append((dt, size))
    return out


def _nbytes(text):
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _parse_shapes(text))


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    traffic: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = field(default_factory=dict)
    # (called_comp, kind) kind in {call, while_body, fusion(skipped)}
    calls: list = field(default_factory=list)
    whiles: list = field(default_factory=list)  # (body, cond)
    const_ints: dict = field(default_factory=dict)  # name -> int
    compares: list = field(default_factory=list)    # rhs operand names
    lines: list = field(default_factory=list)


def _split_computations(hlo: str):
    comps, cur = {}, None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            toks = stripped.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = Comp(name.lstrip("%").split("(")[0])
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            cur.lines.append(stripped)
    return comps


def _analyze_comp(comp: Comp, symtab_cache):
    sym = {}
    for line in comp.lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        out_type = rhs.split(" ", 1)[0] if " " in rhs else rhs
        sym[name] = rhs
        # constants (for trip counts)
        mc = re.match(r"s(?:32|64)\[\]\s+constant\((\-?\d+)\)", rhs)
        if mc:
            comp.const_ints[name] = int(mc.group(1))

        opm = re.search(r"\]\S*\s+([\w\-]+)\(", rhs)
        op = opm.group(1) if opm else ""

        if op == "while":
            body = next(iter(re.findall(r"body=%?([\w\.\-]+)", rhs)), None)
            cond = next(iter(re.findall(r"condition=%?([\w\.\-]+)", rhs)), None)
            mtc = re.search(r'known_trip_count.*?"n":"(\d+)"', rhs)
            trips = int(mtc.group(1)) if mtc else None
            comp.whiles.append((body, cond, trips))
            continue
        if op in ("fusion", "call", "conditional", "custom-call", "reduce",
                  "map", "sort", "scatter", "select-and-scatter"):
            # count IO of the op itself; bodies of fusions are not walked
            comp.traffic += _operand_bytes(rhs, sym) + _nbytes(out_type)
            if op == "call":
                for c in _CALLED_RE.findall(rhs):
                    comp.calls.append(c)
            continue
        for cname in COLLECTIVES:
            if op == cname or op == cname + "-start":
                b = _nbytes(out_type) * _COLL_FACTOR[cname]
                comp.coll_bytes += b
                comp.coll_counts[cname] = comp.coll_counts.get(cname, 0) + 1
                comp.traffic += _operand_bytes(rhs, sym) + _nbytes(out_type)
                break
        else:
            if op in ("dot",):
                comp.flops += _dot_flops(rhs, out_type, sym)
                comp.traffic += _operand_bytes(rhs, sym) + _nbytes(out_type)
            elif op in ("convolution",):
                comp.flops += _conv_flops(rhs, out_type, sym)
                comp.traffic += _operand_bytes(rhs, sym) + _nbytes(out_type)
            elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "copy-done", "copy-start", ""):
                pass
            else:
                comp.traffic += _operand_bytes(rhs, sym) + _nbytes(out_type)
        mcomp = re.search(r"compare\(([^)]*)\)", rhs)
        if mcomp:
            ops = re.findall(r"%([\w\.\-]+)", mcomp.group(1)) or \
                [o.strip() for o in mcomp.group(1).split(",") if o.strip()]
            comp.compares.extend(ops)
    symtab_cache[comp.name] = sym


def _operand_names(rhs):
    m = re.search(r"\(([^)]*)\)", rhs)
    if not m:
        return []
    # operands may print bare ("%name") or typed ("f32[64,128]{1,0} %name");
    # shape commas break naive splitting, so prefer the %-sigil names
    names = re.findall(r"%([\w\.\-]+)", m.group(1))
    if names:
        return names
    return [o.strip().split(" ")[-1]
            for o in m.group(1).split(",") if o.strip()]


def _operand_bytes(rhs, sym):
    total = 0
    for name in _operand_names(rhs):
        d = sym.get(name)
        if d:
            total += _nbytes(d.split(" ")[0])
    return total


def _dot_flops(rhs, out_type, sym):
    out_elems = sum(n for _, n in _parse_shapes(out_type))
    k = 1
    mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    ops = _operand_names(rhs)
    if mlhs and ops:
        lhs_def = sym.get(ops[0], "")
        shapes = _parse_shapes(lhs_def.split(" ")[0])
        mdims = re.search(r"\[([\d,]*)\]", lhs_def)
        if mdims and mdims.group(1):
            dims = [int(d) for d in mdims.group(1).split(",")]
            for ci in mlhs.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(rhs, out_type, sym):
    out_elems = sum(n for _, n in _parse_shapes(out_type))
    ops = _operand_names(rhs)
    kernel_elems = 1
    if len(ops) >= 2:
        kdef = sym.get(ops[1], "")
        mdims = re.search(r"\[([\d,]*)\]", kdef)
        if mdims and mdims.group(1):
            dims = [int(d) for d in mdims.group(1).split(",")]
            kernel_elems = 1
            for d in dims[:-1]:  # exclude output-feature dim (approx)
                kernel_elems *= d
    return 2.0 * out_elems * kernel_elems


def _trip_count(cond: Comp) -> int:
    """Resolve while trip count from a compare against a constant."""
    best = 1
    for name in cond.compares:
        if name in cond.const_ints:
            best = max(best, abs(cond.const_ints[name]))
    return best


def top_collectives(hlo: str, k: int = 20):
    """Largest collective contributors: (op, wire_bytes, trips, total, hint)."""
    comps = _split_computations(hlo)
    symtabs: dict = {}
    for c in comps.values():
        _analyze_comp(c, symtabs)
    # computation -> trip multiplier (product of enclosing while trip counts)
    mult = {name: 1 for name in comps}
    changed = True
    guard = 0
    while changed and guard < 64:
        changed, guard = False, guard + 1
        for c in comps.values():
            for body, cond, trips in c.whiles:
                if trips is None:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                want = mult[c.name] * trips
                if body in mult and mult[body] != want:
                    mult[body] = want
                    changed = True
            for callee in c.calls:
                if callee in mult and mult[callee] != mult[c.name]:
                    mult[callee] = mult[c.name]
                    changed = True
    records = []
    for c in comps.values():
        for line in c.lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            opm = re.search(r"\]\S*\s+([\w\-]+)\(", rhs)
            op = opm.group(1) if opm else ""
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                nb = _nbytes(rhs.split(" ", 1)[0]) * _COLL_FACTOR[base]
                hint = ""
                mh = re.search(r'op_name="([^"]+)"', rhs)
                if mh:
                    hint = mh.group(1)[:90]
                records.append({"op": base, "bytes": nb,
                                "trips": mult.get(c.name, 1),
                                "total": nb * mult.get(c.name, 1),
                                "hint": hint})
    records.sort(key=lambda r: -r["total"])
    return records[:k]


def analyze_hlo(hlo: str, entry: str | None = None) -> dict:
    comps = _split_computations(hlo)
    symtabs: dict = {}
    for c in comps.values():
        _analyze_comp(c, symtabs)

    if entry is None:
        entry = next((n for n in comps if "main" in n or "entry" in n.lower()),
                     next(iter(comps)))

    memo: dict[str, tuple] = {}

    def roll(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return (0.0, 0.0, 0.0, {})
        c = comps[name]
        fl, tr, cb = c.flops, c.traffic, c.coll_bytes
        counts = dict(c.coll_counts)
        for callee in c.calls:
            f2, t2, b2, n2 = roll(callee, depth + 1)
            fl, tr, cb = fl + f2, tr + t2, cb + b2
            for k, v in n2.items():
                counts[k] = counts.get(k, 0) + v
        for body, cond, trips in c.whiles:
            if trips is None:
                trips = _trip_count(comps[cond]) if cond in comps else 1
            f2, t2, b2, n2 = roll(body, depth + 1) if body else (0, 0, 0, {})
            fl, tr, cb = fl + trips * f2, tr + trips * t2, cb + trips * b2
            for k, v in n2.items():
                counts[k] = counts.get(k, 0) + trips * v
        memo[name] = (fl, tr, cb, counts)
        return memo[name]

    flops, traffic, coll_bytes, coll_counts = roll(entry)
    return {"flops": flops, "traffic_bytes": traffic,
            "collective_bytes": coll_bytes, "collective_counts": coll_counts,
            "entry": entry, "num_computations": len(comps)}


# v5e roofline constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link


def roofline_terms(analysis: dict) -> dict:
    """Per-chip three-term roofline (seconds). Shapes in the post-SPMD module
    are per-device, so no further division by chip count."""
    t_compute = analysis["flops"] / PEAK_FLOPS
    t_memory = analysis["traffic_bytes"] / HBM_BW
    t_coll = analysis["collective_bytes"] / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    total = max(t_compute, t_memory, t_coll)
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "bottleneck": dom[1],
            "roofline_s": total}
