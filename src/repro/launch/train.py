"""End-to-end training driver.

Two workloads behind one CLI and ONE ``PopTrainer`` code path:

  * ``--arch <id>``   — LM population training on the synthetic token
                        pipeline (the paper's §5.3-style study);
  * ``--algo <name>`` — RL population training on a pure-JAX env via the
                        fused ``repro.rollout`` iteration.  Algorithm
                        selection is the ``repro.rl.ALGOS`` *registry*
                        (td3 | sac | dqn | ppo — off- and on-policy through
                        the same experience-pipeline contract), so unknown
                        names are rejected with the valid set and adding an
                        algorithm never touches this file.

Production features exercised here (scaled down to whatever devices exist):
  * config-driven arch selection (--arch) + population size (--population)
  * the unified ``repro.pop`` API: ONE ``PopTrainer`` code path for every
    population size — size 1 is the degenerate (NoEvolution) case, so there
    is no single-agent/population branching anywhere in this file
  * the paper's protocol: one jit'd vmapped train step updates every member,
    per-member learning-rate scale as a dynamic hyperparameter
  * pluggable evolution (--strategy pbt|cem|none) and update backend
    (--backend vectorized|sequential|sharded|islands) as one-line config
    changes; islands plans an ``repro.elastic.IslandLayout`` over
    ``--devices`` accelerators (default: all of them)
  * on-device PBT exploit/explore every --pbt-interval steps (fitness =
    -loss window mean, window capped at the config's fitness_window)
  * checkpoint/restart: atomic async checkpoints every --ckpt-every steps,
    ``--resume auto`` restarts from the latest one (fault tolerance)
  * elastic restart: ``--resize auto`` accepts a checkpoint whose
    population differs from ``--population`` — the worst members are
    dropped (or PBT clones refill) via ``repro.elastic.restore_elastic``,
    so losing accelerators between runs never strands a checkpoint
  * synthetic sharded token pipeline with restart-stable streams
  * persistent XLA compilation cache (``--compile-cache DIR``, shared with
    ``launch/serve.py``) so restarts don't pay cold compiles.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.configs.base import HyperSpace, PopulationConfig
from repro.data import host_batches
from repro.pop import LMAgent, PopTrainer
from repro.telemetry import make_telemetry


def _telemetry(args, **meta):
    """One telemetry object per run: console sink always (the single
    formatting path), JSONL into ``--log-dir`` when given (what
    ``tools/report.py`` replays), compile tracking on."""
    return make_telemetry(args.log_dir, meta=dict(
        meta, seed=args.seed, population=args.population,
        strategy=args.strategy, backend=args.backend))


def _run_rl(args):
    """RL branch: registry-selected algorithm on a pure-JAX env, trained
    through ``PopTrainer.attach_rollout`` / ``run_env_loop`` (the fused
    iteration — off-policy or on-policy per the agent's experience kind)."""
    from repro.envs import make
    from repro.rl import get_algo, make_agent

    algo = get_algo(args.algo)   # ValueError lists the registry on typos
    env = make(args.env)
    agent = make_agent(args.algo, env.spec)
    n = args.population
    print(f"[train] algo={algo.name} env={args.env} pop={n} "
          f"strategy={args.strategy} backend={args.backend} "
          f"experience={algo.experience_kind}")

    pcfg = PopulationConfig(
        size=n, strategy=args.strategy, backend=args.backend,
        num_steps=args.updates_per_iter, pbt_interval=args.pbt_interval,
        hyper_space=algo.hyper_space, donate=False,  # async ckpts read state
        fused_adam=args.fused_adam or args.fused_linear,
        fused_linear=args.fused_linear)
    layout = None
    if args.backend == "islands":
        from repro.elastic import plan_layout
        layout = plan_layout(args.devices or len(jax.devices()), n)
        print(f"[train] {layout}")
    telemetry = _telemetry(args, workload="rl", algo=algo.name, env=args.env)
    trainer = PopTrainer(agent, pcfg, seed=args.seed, layout=layout,
                         checkpoint_dir=args.ckpt_dir, telemetry=telemetry)
    trainer.attach_rollout(env, num_envs=args.num_envs,
                           collect_steps=args.collect_steps,
                           batch_size=args.batch, epochs=args.epochs,
                           policy_lag=args.policy_lag,
                           chunk_steps=args.chunk_steps)
    if args.resume == "auto":
        meta = trainer._mgr.peek_extra()   # strict: size/fitness guaranteed
        if (args.resize == "auto" and meta is not None
                and meta["size"] != n):
            from repro.elastic import restore_elastic
            with telemetry.compile_scope("resize"):
                resumed, lineage = restore_elastic(trainer)
            print(f"[train] elastic resume from step {resumed}: population "
                  f"{meta['size']} -> {n}, lineage={np.asarray(lineage)}")
        elif trainer.resume() is not None:
            print(f"[train] resumed at trainer step {trainer.step_count}")

    t0 = time.time()
    best = {"fitness": float("-inf")}

    def on_iter(it, metrics, stats, fitness, lineage):
        telemetry.tick_profile(it, args.profile, iters=args.profile_iters)
        if fitness is not None:
            best["fitness"] = max(best["fitness"], float(np.max(fitness)))
        if (it + 1) % args.ckpt_every == 0 or it == args.steps - 1:
            trainer.save()

    trainer.run_env_loop(args.steps, eval_every=args.eval_every,
                         on_iter=on_iter, fused=args.fused_epoch)
    trainer.wait()
    telemetry.record("run_end", best_fitness=best["fitness"],
                     compiles=telemetry.compile_count,
                     compile_secs=round(telemetry.compile_secs, 3))
    telemetry.close()
    print(f"[train] done in {time.time() - t0:.1f}s, "
          f"best fitness {best['fitness']:+.2f}")
    return best["fitness"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM config id (LM workload; exclusive with --algo)")
    ap.add_argument("--algo", default=None,
                    help="RL algorithm from the repro.rl.ALGOS registry "
                    "(td3|sac|dqn|ppo; exclusive with --arch)")
    ap.add_argument("--env", default="pendulum",
                    help="pure-JAX env name for the --algo workload")
    ap.add_argument("--num-envs", type=int, default=8)
    ap.add_argument("--collect-steps", type=int, default=32)
    ap.add_argument("--policy-lag", type=int, default=None,
                    choices=[0, 1],
                    help="overlapped acting engine (repro.rollout."
                    "OverlapEngine): 0 = split collect/update programs, "
                    "serial schedule (bitwise-equal to the fused "
                    "iteration); 1 = pipelined — collect(t+1) is enqueued "
                    "before the host blocks on update(t), acting params "
                    "one update stale; default: serial fused engine "
                    "(incompatible with --fused-epoch at lag 1)")
    ap.add_argument("--chunk-steps", type=int, default=None,
                    help="collect in chunks of this many acting steps, "
                    "folding each chunk into the experience store so "
                    "memory stays bounded at thousands of envs per member "
                    "(must divide --collect-steps; results are bitwise-"
                    "identical to unchunked)")
    ap.add_argument("--updates-per-iter", type=int, default=32,
                    help="chained off-policy updates per fused iteration")
    ap.add_argument("--epochs", type=int, default=4,
                    help="on-policy (ppo) epochs per fused iteration")
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--population", type=int, default=1)
    ap.add_argument("--strategy", default="pbt",
                    choices=["pbt", "cem", "none"])
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential", "sharded",
                             "islands"])
    ap.add_argument("--pbt-interval", type=int, default=50)
    ap.add_argument("--fused-adam", action="store_true",
                    help="hoist every member's Adam step into the "
                    "population-level repro.optim.population_adam "
                    "(kernels/pop_adam on TPU); numerics unchanged")
    ap.add_argument("--fused-linear", action="store_true",
                    help="route population-batched linear layers inside "
                    "the fused update through kernels/pop_matmul "
                    "(implies --fused-adam)")
    ap.add_argument("--fused-epoch", action="store_true",
                    help="run whole train–evolve epochs (pbt_interval "
                    "iterations + evals + evolve) as ONE jitted call; "
                    "needs --steps a multiple of --pbt-interval and "
                    "--eval-every dividing it (bit-exact vs the eager "
                    "loop — tests/test_fused_epoch.py)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--devices", type=int, default=0,
                    help="devices to lay the islands over (0 = all); the "
                    "layout is planned by repro.elastic.plan_layout")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="preferred model-parallel width inside each "
                    "island (islands backend): each member is sharded "
                    "over its island's (data, model) sub-mesh by the "
                    "models/sharding rules — how a 1.6B member fits per "
                    "island")
    ap.add_argument("--resize", default="strict", choices=["strict", "auto"],
                    help="auto: resume a checkpoint whose population size "
                    "differs from --population via elastic re-layout "
                    "(worst members dropped / PBT clones refill)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent jax compilation cache directory: "
                    "restarts (and launch/serve.py, pointed at the same "
                    "DIR) reuse compiled executables instead of paying "
                    "cold XLA compiles")
    ap.add_argument("--log-dir", default=None, metavar="DIR",
                    help="write structured run telemetry (phase timers, "
                    "per-member fitness/hypers, lineage events, compile "
                    "tracking) as DIR/telemetry.jsonl — tools/report.py "
                    "reconstructs the PBT family tree and timings from it")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace into DIR for "
                    "a bounded window (starts after the warmup iteration)")
    ap.add_argument("--profile-iters", type=int, default=3,
                    help="iterations the --profile trace window spans")
    args = ap.parse_args(argv)

    if (args.arch is None) == (args.algo is None):
        ap.error("pass exactly one of --arch (LM) or --algo (RL)")
    if args.compile_cache:
        from repro import compat
        compat.enable_compilation_cache(args.compile_cache)
    if args.algo is not None:
        return _run_rl(args)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1), seed=args.seed)
    n = args.population
    print(f"[train] arch={cfg.name} pop={n} strategy={args.strategy} "
          f"backend={args.backend} devices={len(jax.devices())}")

    pcfg = PopulationConfig(
        size=n, strategy=args.strategy, backend=args.backend,
        pbt_interval=args.pbt_interval, donate=False,  # async ckpts read state
        fused_adam=args.fused_adam or args.fused_linear,
        fused_linear=args.fused_linear,
        hyper_space=HyperSpace(
            log_uniform=(("lr_scale", 0.1, 10.0),
                         ("weight_decay", 1e-3, 0.3)),
            uniform=(("warmup_frac", 0.01, 0.25),)))
    layout = None
    if args.backend == "islands":
        from repro.elastic import plan_layout
        layout = plan_layout(args.devices or len(jax.devices()), n,
                             preferred_model=args.model_axis)
        print(f"[train] {layout}")
    telemetry = _telemetry(args, workload="lm", arch=cfg.name)
    trainer = PopTrainer(LMAgent(cfg, tcfg), pcfg, seed=args.seed,
                         layout=layout, checkpoint_dir=args.ckpt_dir,
                         telemetry=telemetry)
    trainer.tokens_per_step = args.batch * args.seq_len

    start_step = 0
    if args.resume == "auto":
        meta = trainer._mgr.peek_extra()   # strict: size/fitness guaranteed
        if (args.resize == "auto" and meta is not None
                and meta["size"] != n):
            from repro.elastic import restore_elastic
            with telemetry.compile_scope("resize"):
                resumed, lineage = restore_elastic(trainer)
            print(f"[train] elastic resume from step {resumed}: population "
                  f"{meta['size']} -> {n}, lineage={np.asarray(lineage)}")
        else:
            resumed = trainer.resume()
            if resumed is not None:
                print(f"[train] resumed from step {resumed}")
        if resumed is not None:
            start_step = resumed + 1

    gen = host_batches(cfg.vocab_size, args.batch * n, args.seq_len,
                       seed=args.seed, start_step=start_step)

    def next_batch():
        # phase-timed like the RL branch's collect/update split, so
        # tools/report.py sees where LM wall-clock goes
        with telemetry.phase("data"):
            tokens = jnp.asarray(next(gen))
        if cfg.frontend == "audio_frames":
            batch = {"tokens": tokens,
                     "embeds": jnp.zeros(tokens.shape + (cfg.d_model,),
                                         jnp.dtype(cfg.dtype))}
        elif cfg.frontend == "vision_patches":
            batch = {"tokens": tokens,
                     "patch_embeds": jnp.zeros(
                         (tokens.shape[0], cfg.num_frontend_positions,
                          cfg.d_model), jnp.dtype(cfg.dtype))}
        else:
            batch = {"tokens": tokens}
        return jax.tree.map(
            lambda x: x.reshape((n, args.batch) + x.shape[1:]), batch)

    last = {"loss": float("nan")}
    t0 = time.time()

    def on_step(step, metrics, lineage):
        telemetry.tick_profile(step - start_step, args.profile,
                               iters=args.profile_iters)
        # iteration/evolve rows flow through the telemetry console sink;
        # only the checkpoint cadence (which wants a materialized loss for
        # the extras) stays host-side here
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            last["loss"] = float(jnp.mean(metrics["loss"]))
            trainer.save({"loss": last["loss"]})

    metrics = trainer.run(args.steps, lambda step: next_batch(),
                          on_step=on_step)
    trainer.wait()
    if last["loss"] != last["loss"] and metrics is not None:
        last["loss"] = float(jnp.mean(metrics["loss"]))
    telemetry.record("run_end", final_loss=last["loss"],
                     compiles=telemetry.compile_count,
                     compile_secs=round(telemetry.compile_secs, 3))
    telemetry.close()
    print(f"[train] done in {time.time() - t0:.1f}s, "
          f"final loss {last['loss']:.4f}")
    return last["loss"]


if __name__ == "__main__":
    main()
