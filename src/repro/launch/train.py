"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Production features exercised here (scaled down to whatever devices exist):
  * config-driven arch selection (--arch) + population size (--population)
  * the paper's protocol: one jit'd vmapped train step updates every member,
    per-member learning-rate scale as a dynamic hyperparameter
  * on-device PBT exploit/explore every --pbt-interval steps (fitness =
    -loss window mean)
  * checkpoint/restart: atomic async checkpoints every --ckpt-every steps,
    ``--resume auto`` restarts from the latest one (fault tolerance)
  * elastic re-layout: the mesh is rebuilt from the *surviving* device count
    at startup; because population state is just a stacked pytree, a member
    count that no longer divides the mesh is handled by PBT cloning
    (population-based training is naturally elastic)
  * synthetic sharded token pipeline with restart-stable streams.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.configs.base import HyperSpace, PopulationConfig
from repro.core import pbt_step, sample_hypers
from repro.data import host_batches
from repro.launch.mesh import make_host_mesh
from repro.models import lm as lm_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--population", type=int, default=1)
    ap.add_argument("--pbt-interval", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1), seed=args.seed)
    n = args.population
    print(f"[train] arch={cfg.name} pop={n} devices={len(jax.devices())}")

    key = jax.random.PRNGKey(args.seed)
    opt_init, train_step = lm_mod.make_train_step(cfg, tcfg)

    if n == 1:
        params = lm_mod.init_params(key, cfg)
        opt = opt_init(params)
        hypers = None
    else:
        params = jax.vmap(lambda k: lm_mod.init_params(k, cfg))(
            jax.random.split(key, n))
        opt = jax.vmap(opt_init)(params)
        space = HyperSpace(log_uniform=(("lr_scale", 0.1, 10.0),))
        hypers = sample_hypers(key, space, n)
        pcfg = PopulationConfig(size=n, pbt_interval=args.pbt_interval,
                                hyper_space=space)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start_step = 0
    if args.resume == "auto" and mgr.latest() is not None:
        (params, opt), extra = mgr.restore((params, opt))
        start_step = extra["step"] + 1
        print(f"[train] resumed from step {extra['step']}")

    if n == 1:
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    else:
        def pop_step(p, o, b, s, hyp):
            return jax.vmap(
                lambda pi, oi, bi, sc: train_step(pi, oi, bi, s, lr_scale=sc),
                in_axes=(0, 0, 0, 0))(p, o, b, hyp["lr_scale"])
        step_fn = jax.jit(pop_step, donate_argnums=(0, 1))

    gen = host_batches(cfg.vocab_size, args.batch * max(n, 1), args.seq_len,
                       seed=args.seed, start_step=start_step)
    window = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        tokens = jnp.asarray(next(gen))
        if cfg.frontend == "audio_frames":
            batch = {"tokens": tokens,
                     "embeds": jnp.zeros(tokens.shape + (cfg.d_model,),
                                         jnp.dtype(cfg.dtype))}
        elif cfg.frontend == "vision_patches":
            batch = {"tokens": tokens,
                     "patch_embeds": jnp.zeros(
                         (tokens.shape[0], cfg.num_frontend_positions,
                          cfg.d_model), jnp.dtype(cfg.dtype))}
        else:
            batch = {"tokens": tokens}
        if n > 1:
            batch = jax.tree.map(
                lambda x: x.reshape((n, args.batch) + x.shape[1:]), batch)
            params, opt, metrics = step_fn(params, opt, batch,
                                           jnp.asarray(step), hypers)
            loss = float(jnp.mean(metrics["loss"]))
            window.append(np.asarray(metrics["loss"]))
        else:
            params, opt, metrics = step_fn(params, opt, batch,
                                           jnp.asarray(step))
            loss = float(metrics["loss"])

        if n > 1 and (step + 1) % args.pbt_interval == 0:
            fitness = -jnp.mean(jnp.stack(window[-pcfg.fitness_window:]),
                                axis=0)
            key, kp = jax.random.split(key)
            (params, opt), hypers, parents = pbt_step(
                kp, (params, opt), hypers, fitness, pcfg)
            print(f"[pbt] step {step + 1} fitness={np.asarray(fitness).round(3)}"
                  f" parents={np.asarray(parents)}")

        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            mgr.save_async(step, (params, opt), {"loss": loss})
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"({(time.time() - t0) / max(step - start_step + 1, 1):.2f}"
                  f" s/step)", flush=True)
    mgr.wait()
    print(f"[train] done in {time.time() - t0:.1f}s, final loss {loss:.4f}")
    return loss


if __name__ == "__main__":
    main()
