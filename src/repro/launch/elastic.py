"""Elastic re-layout: resume a checkpoint on a DIFFERENT mesh.

The fault-tolerance contract at 1000-node scale: when nodes are lost, the
launcher rebuilds a smaller mesh from the survivors and training resumes
from the latest checkpoint.  Because checkpoints are saved as host numpy
(full tensors) and all shardings are derived *functions* of the current
mesh (repro.models.sharding rules), re-layout is: rebuild mesh -> recompute
NamedShardings -> device_put.  Population members shrink gracefully: if the
surviving mesh no longer fits the population, the worst members are dropped
(PBT clones refill at the next exploit step — population training is
naturally elastic).

``plan_mesh`` picks the largest (data, model) grid for a surviving device
count given a preferred model-parallel width.
"""
from __future__ import annotations

import jax
import numpy as np
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.sharding import param_specs


def plan_mesh(num_devices: int, *, preferred_model: int = 16,
              multi_pod: bool = False):
    """Largest usable (data, model) grid for the surviving devices."""
    model = preferred_model
    while model > 1 and (num_devices % model or num_devices // model < 1):
        model //= 2
    data = num_devices // model
    axes = ("data", "model")
    shape = (data, model)
    if multi_pod and data % 2 == 0:
        shape, axes = (2, data // 2, model), ("pod", "data", "model")
    return compat.make_mesh(shape, axes)


def relayout(tree, mesh):
    """Place a host (or differently-sharded) pytree onto ``mesh`` using the
    rule-derived shardings."""
    specs = param_specs(tree, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(tree, shardings)


def shrink_population(pop_tree, fitness, new_size: int):
    """Keep the ``new_size`` fittest members (elastic population shrink)."""
    order = np.argsort(np.asarray(fitness))[::-1][:new_size]
    keep = np.sort(order)
    return jax.tree.map(lambda x: x[keep], pop_tree), keep
