"""PPO (Schulman et al., 2017) — functional, population-vectorizable.

The on-policy member of the repo's algorithm family: the clipped surrogate
objective with value clipping and an entropy bonus, over minibatches of a
fixed-length GAE-processed rollout (``repro.data.TrajectoryBuffer``).  Like
td3/sac/dqn, every hyperparameter a PBT study would tune is a *dynamic*
input (the ``hypers`` dict) so one compiled update serves all members with
their own values under ``vmap``:

    lr, clip_eps, entropy_coef, value_coef, discount, gae_lambda.

(``discount`` / ``gae_lambda`` are consumed on the GAE side of the
pipeline — ``repro.rollout.engine`` reads them from the same per-member
dict when it computes advantages on device.)

Acting contract: PPO is the repo's first algorithm whose policy emits
*extras* — ``explore`` returns ``(action, {"log_prob", "value"})`` and the
generalized ``repro.rollout.Collector`` records them into the trajectory,
because the update must evaluate the ratio against the log-prob of the
distribution that actually sampled the action.  Continuous actions are an
unsquashed diagonal gaussian around a tanh mean with a learnable
state-independent ``log_std`` (the env clips at its boundary; the stored
action stays the raw sample so the stored log-prob stays exact); discrete
actions are a categorical over logits.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets


DEFAULT_HYPERS = {
    "lr": 3e-4, "clip_eps": 0.2, "entropy_coef": 0.01, "value_coef": 0.5,
    "discount": 0.99, "gae_lambda": 0.95,
}
LOG_STD_INIT = -0.5

_opt_init, _opt_update = adam(3e-4)


class PPOState(NamedTuple):
    params: Any            # {"actor", "critic"} (+ "log_std" if continuous)
    opt: Any
    step: jnp.ndarray


def init(key, obs_dim: int, act_dim: int, discrete: bool = False,
         hidden=nets.HIDDEN) -> PPOState:
    ka, kc = jax.random.split(key)
    actor = (nets.logits_init(ka, obs_dim, act_dim, hidden=hidden) if discrete
             else nets.actor_init(ka, obs_dim, act_dim, hidden=hidden))
    params = {"actor": actor,
              "critic": nets.value_init(kc, obs_dim, hidden=hidden)}
    if not discrete:
        # Explicit dtype: a weak-typed init leaf would flip to strong after
        # the first update and retrace the whole fused iteration once.
        params["log_std"] = jnp.full((act_dim,), LOG_STD_INIT, jnp.float32)
    return PPOState(params=params, opt=_opt_init(params),
                    step=jnp.zeros((), jnp.int32))


def _dist(params, obs):
    """(mean, log_std) for continuous params, (logits, None) for discrete."""
    if "log_std" in params:
        return nets.actor_apply(params["actor"], obs), params["log_std"]
    return nets.mlp_apply(params["actor"], obs), None


def policy(params, obs, key=None):
    """Deterministic action when ``key`` is None (evaluation), else a
    sample from the acting distribution."""
    out, log_std = _dist(params, obs)
    if log_std is None:
        if key is None:
            return jnp.argmax(out, axis=-1)
        return jax.random.categorical(key, out, axis=-1)
    if key is None:
        return out
    return out + jnp.exp(log_std) * jax.random.normal(key, out.shape)


def explore(params, obs, key, hypers=None):
    """The acting step: ``(action, extras)`` with the log-prob of the
    sampled action and the state value — the on-policy extras the
    generalized collector stores (``repro.data.trajectory_spec``)."""
    action = policy(params, obs, key)
    logp, _ = log_prob_entropy(params, obs, action)
    return action, {"log_prob": logp, "value": value(params, obs)}


def value(params, obs):
    return nets.value_apply(params["critic"], obs)


def log_prob_entropy(params, obs, actions):
    out, log_std = _dist(params, obs)
    if log_std is None:
        return (nets.categorical_log_prob(out, actions),
                nets.categorical_entropy(out))
    return (nets.gaussian_log_prob(out, log_std, actions),
            jnp.broadcast_to(nets.gaussian_entropy(log_std),
                             out.shape[:-1]))


def update(state: PPOState, batch, hypers=None) -> tuple[PPOState, dict]:
    """One clipped-surrogate step on a minibatch of GAE-processed rollout
    data: ``batch`` holds obs, action, log_prob, value (both as collected),
    advantage and return (``repro.rollout.engine`` builds them on device).

    Advantages are normalized per minibatch (the standard PPO detail); the
    value loss is clipped around the collected value with the same
    ``clip_eps`` as the ratio."""
    h = dict(DEFAULT_HYPERS)
    if hypers:
        h.update(hypers)

    adv = batch["advantage"]
    adv = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)

    def loss_fn(params):
        logp, entropy = log_prob_entropy(params, batch["obs"],
                                         batch["action"])
        ratio = jnp.exp(logp - batch["log_prob"])
        clipped = jnp.clip(ratio, 1.0 - h["clip_eps"], 1.0 + h["clip_eps"])
        pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))

        v = value(params, batch["obs"])
        v_clip = batch["value"] + jnp.clip(v - batch["value"],
                                           -h["clip_eps"], h["clip_eps"])
        v_loss = 0.5 * jnp.mean(jnp.maximum((v - batch["return"]) ** 2,
                                            (v_clip - batch["return"]) ** 2))
        ent = jnp.mean(entropy)
        loss = pg_loss + h["value_coef"] * v_loss - h["entropy_coef"] * ent
        kl = jnp.mean(batch["log_prob"] - logp)
        return loss, {"policy_loss": pg_loss, "value_loss": v_loss,
                      "entropy": ent, "approx_kl": kl}

    (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state.params)
    upd, opt = _opt_update(grads, state.opt, lr_override=h["lr"])
    params = apply_updates(state.params, upd)
    return PPOState(params=params, opt=opt, step=state.step + 1), metrics


def _member_loss(params, batch, adv, h):
    """Stock clipped-surrogate loss with explicit args (vmappable)."""
    logp, entropy = log_prob_entropy(params, batch["obs"], batch["action"])
    ratio = jnp.exp(logp - batch["log_prob"])
    clipped = jnp.clip(ratio, 1.0 - h["clip_eps"], 1.0 + h["clip_eps"])
    pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))

    v = value(params, batch["obs"])
    v_clip = batch["value"] + jnp.clip(v - batch["value"],
                                       -h["clip_eps"], h["clip_eps"])
    v_loss = 0.5 * jnp.mean(jnp.maximum((v - batch["return"]) ** 2,
                                        (v_clip - batch["return"]) ** 2))
    ent = jnp.mean(entropy)
    loss = pg_loss + h["value_coef"] * v_loss - h["entropy_coef"] * ent
    kl = jnp.mean(batch["log_prob"] - logp)
    return loss, {"policy_loss": pg_loss, "value_loss": v_loss,
                  "entropy": ent, "approx_kl": kl}


def _pop_log_prob_entropy(params, obs, actions):
    """Population-level ``log_prob_entropy``: member-stacked params,
    ``obs`` (N,B,obs), ``actions`` (N,B[,act]) -> (N,B) each."""
    if "log_std" in params:
        mean = nets.pop_actor_apply(params["actor"], obs)
        log_std = params["log_std"][:, None, :]        # (N,1,A) vs (N,B,A)
        return (nets.gaussian_log_prob(mean, log_std, actions),
                jnp.broadcast_to(nets.gaussian_entropy(log_std),
                                 mean.shape[:-1]))
    logits = nets.pop_mlp_apply(params["actor"], obs)
    return (nets.categorical_log_prob(logits, actions),
            nets.categorical_entropy(logits))


def make_population_update(*, fused_linear: bool = False, fused=None):
    """Population-level PPO update: per-member clipped-surrogate gradients
    with the single Adam application hoisted into
    ``repro.optim.population_adam`` (see ``repro.rl.fused``)."""
    from repro.optim.pop_adam import population_adam
    from repro.rl.fused import pop_hypers
    _, pa = population_adam(3e-4, fused=fused)

    def pop_loss(params, batch, adv, h):
        logp, entropy = _pop_log_prob_entropy(params, batch["obs"],
                                              batch["action"])
        ratio = jnp.exp(logp - batch["log_prob"])
        clip_eps = h["clip_eps"][:, None]
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
        pg = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv), axis=1)

        v = nets.pop_value_apply(params["critic"], batch["obs"])
        v_clip = batch["value"] + jnp.clip(v - batch["value"],
                                           -clip_eps, clip_eps)
        vl = 0.5 * jnp.mean(jnp.maximum((v - batch["return"]) ** 2,
                                        (v_clip - batch["return"]) ** 2),
                            axis=1)
        ent = jnp.mean(entropy, axis=1)
        per = pg + h["value_coef"] * vl - h["entropy_coef"] * ent
        kl = jnp.mean(batch["log_prob"] - logp, axis=1)
        return jnp.sum(per), {"policy_loss": pg, "value_loss": vl,
                              "entropy": ent, "approx_kl": kl}

    def update(state: PPOState, batch, hypers=None):
        n = state.step.shape[0]
        h = pop_hypers(DEFAULT_HYPERS, hypers, n)

        adv = batch["advantage"]                               # (N, B)
        adv = (adv - jnp.mean(adv, axis=1, keepdims=True)) / \
            (jnp.std(adv, axis=1, keepdims=True) + 1e-8)

        if fused_linear:
            (_, metrics), grads = jax.value_and_grad(
                pop_loss, has_aux=True)(state.params, batch, adv, h)
        else:
            (_, metrics), grads = jax.vmap(jax.value_and_grad(
                _member_loss, has_aux=True))(state.params, batch, adv, h)
        params, opt = pa(state.params, grads, state.opt, lr_override=h["lr"])
        return PPOState(params=params, opt=opt, step=state.step + 1), metrics

    return update
