"""Actor/critic networks for SAC/TD3/DQN (the paper's MLP parametrizations).

Standard sizes from Haarnoja et al. / Fujimoto et al.: 256-256 MLPs.

The ``pop_*_apply`` family evaluates the SAME parametrizations over
member-stacked parameter trees (leaves ``(N, ...)``) and member-batched
inputs ``(N, B, ...)`` in one population-level call — the layout the
``kernels/pop_matmul`` Pallas kernel was written for.  Routing is decided
per linear by ``fused``:

  * ``None`` (auto)  — the kernel on TPU backends when
    :func:`repro.kernels.pop_matmul.supports_shapes` accepts the tiling;
    everywhere else a batched-``einsum`` fallback that lowers to the same
    ``dot_general`` as ``vmap`` of the per-member apply (bitwise identical).
  * ``True``         — force the kernel (interpret mode off-TPU; CPU
    validation only), still falling back on untileable shapes.
  * ``False``        — always the jnp fallback.

The kernel path is differentiable: a ``custom_vjp`` computes the backward
matmuls as batched einsums, so ``jax.grad`` through a population-level loss
works on the fused path too (the ``fused_linear`` flag of the rl modules'
``make_population_update``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn.basic import mlp_init, mlp_apply, dqn_torso_init, dqn_torso_apply


HIDDEN = (256, 256)


def actor_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    return mlp_init(key, [obs_dim, *hidden, act_dim])


def actor_apply(params, obs):
    return jnp.tanh(mlp_apply(params, obs))


def gaussian_actor_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    return mlp_init(key, [obs_dim, *hidden, 2 * act_dim])


def gaussian_actor_apply(params, obs):
    out = mlp_apply(params, obs)
    mean, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, -20.0, 2.0)
    return mean, log_std


def sample_squashed(key, mean, log_std):
    """Tanh-squashed gaussian sample + log-prob (SAC)."""
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    pre = mean + std * eps
    act = jnp.tanh(pre)
    logp = jnp.sum(
        -0.5 * (eps ** 2 + 2 * log_std + jnp.log(2 * jnp.pi))
        - jnp.log(jnp.maximum(1 - act ** 2, 1e-6)), axis=-1)
    return act, logp


def logits_init(key, obs_dim: int, num_actions: int, hidden=HIDDEN):
    """Categorical-policy head (raw logits; apply with ``mlp_apply``)."""
    return mlp_init(key, [obs_dim, *hidden, num_actions])


def value_init(key, obs_dim: int, hidden=HIDDEN):
    """State-value head V(s) (PPO's critic — no action input)."""
    return mlp_init(key, [obs_dim, *hidden, 1])


def value_apply(params, obs):
    return mlp_apply(params, obs)[..., 0]


def gaussian_log_prob(mean, log_std, actions):
    """Diagonal-gaussian log-density of ``actions`` (sum over act dims)."""
    var = jnp.exp(2.0 * log_std)
    return jnp.sum(-0.5 * ((actions - mean) ** 2 / var + 2.0 * log_std
                           + jnp.log(2.0 * jnp.pi)), axis=-1)


def gaussian_entropy(log_std):
    return jnp.sum(log_std + 0.5 * jnp.log(2.0 * jnp.pi * jnp.e), axis=-1)


def categorical_log_prob(logits, actions):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(
        logp, actions[..., None].astype(jnp.int32), axis=-1)[..., 0]


def categorical_entropy(logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def critic_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    k1, k2 = jax.random.split(key)
    return {"q1": mlp_init(k1, [obs_dim + act_dim, *hidden, 1]),
            "q2": mlp_init(k2, [obs_dim + act_dim, *hidden, 1])}


def critic_apply(params, obs, act):
    x = jnp.concatenate([obs, act], axis=-1)
    return (mlp_apply(params["q1"], x)[..., 0],
            mlp_apply(params["q2"], x)[..., 0])


def q_net_init(key, obs_dim: int, num_actions: int, hidden=HIDDEN,
               conv_torso: bool = False):
    if conv_torso:  # Atari-style: 84x84x4 frames
        k1, k2 = jax.random.split(key)
        return {"torso": dqn_torso_init(k1),
                "head": mlp_init(k2, [3136, 512, num_actions])}
    return {"head": mlp_init(key, [obs_dim, *hidden, num_actions])}


def q_net_apply(params, obs):
    if "torso" in params:
        obs = dqn_torso_apply(params["torso"], obs)
    return mlp_apply(params["head"], obs)


# ---------------------------------------------------------------------------
# population-batched applies (member-stacked params, (N, B, ...) inputs)
# ---------------------------------------------------------------------------


def _use_pop_matmul(fused, x, w) -> bool:
    if fused is None:
        use = jax.default_backend() == "tpu"
    else:
        use = bool(fused)
    if not use:
        return False
    from repro.kernels.pop_matmul import supports_shapes
    return supports_shapes(x.shape[1], x.shape[2], w.shape[2])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _pop_matmul_vjp(x, w, b, interpret):
    from repro.kernels.pop_matmul import pop_matmul
    return pop_matmul(x, w, b, activation="none", interpret=interpret)


def _pop_matmul_fwd(x, w, b, interpret):
    return _pop_matmul_vjp(x, w, b, interpret), (x, w)


def _pop_matmul_bwd(interpret, res, dy):
    # backward matmuls as batched einsums: members are independent, so the
    # population axis just rides along
    x, w = res
    dx = jnp.einsum("nbm,nkm->nbk", dy, w)
    dw = jnp.einsum("nbk,nbm->nkm", x, dy)
    db = jnp.sum(dy, axis=1)
    return dx, dw, db


_pop_matmul_vjp.defvjp(_pop_matmul_fwd, _pop_matmul_bwd)


def pop_linear_apply(p, x, *, activation: str = "none", fused=None):
    """Member-stacked linear: ``p`` {"w": (N,K,M), "b": (N,M)}, ``x``
    (N,B,K) -> act(x @ w + b), (N,B,M).  The jnp fallback lowers to the
    same batched ``dot_general`` as ``vmap(linear_apply)`` (bitwise)."""
    w, b = p["w"], p.get("b")
    if b is not None and _use_pop_matmul(fused, x, w):
        y = _pop_matmul_vjp(x, w, b, jax.default_backend() != "tpu")
    else:
        y = jnp.einsum("nbk,nkm->nbm", x, w)
        if b is not None:
            y = y + b[:, None, :]
    if activation == "relu":
        y = jax.nn.relu(y)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "none":
        raise ValueError(f"pop_linear_apply: unsupported activation "
                         f"{activation!r} (none|relu|tanh)")
    return y


def pop_mlp_apply(p, x, *, activation: str = "relu",
                  final_activation: str | None = None, fused=None):
    """``mlp_apply`` over member-stacked params — same layer naming, same
    activation placement, population-level."""
    n = len(p)
    for i in range(n):
        inner = activation if i < n - 1 else (final_activation or "none")
        x = pop_linear_apply(p[f"layer_{i}"], x, activation=inner,
                             fused=fused)
    return x


def pop_actor_apply(params, obs, *, fused=None):
    """Population-level ``actor_apply``: tanh MLP, (N,B,obs) -> (N,B,act)."""
    return pop_mlp_apply(params, obs, final_activation="tanh", fused=fused)


def pop_gaussian_actor_apply(params, obs, *, fused=None):
    out = pop_mlp_apply(params, obs, fused=fused)
    mean, log_std = jnp.split(out, 2, axis=-1)
    return mean, jnp.clip(log_std, -20.0, 2.0)


def pop_value_apply(params, obs, *, fused=None):
    return pop_mlp_apply(params, obs, fused=fused)[..., 0]


def pop_critic_apply(params, obs, act, *, fused=None):
    x = jnp.concatenate([obs, act], axis=-1)
    return (pop_mlp_apply(params["q1"], x, fused=fused)[..., 0],
            pop_mlp_apply(params["q2"], x, fused=fused)[..., 0])


def pop_q_net_apply(params, obs, *, fused=None):
    if "torso" in params:
        raise ValueError("pop_q_net_apply: the Atari conv torso has no "
                         "population-batched path (MLP q-nets only)")
    return pop_mlp_apply(params["head"], obs, fused=fused)
