"""Actor/critic networks for SAC/TD3/DQN (the paper's MLP parametrizations).

Standard sizes from Haarnoja et al. / Fujimoto et al.: 256-256 MLPs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.basic import mlp_init, mlp_apply, dqn_torso_init, dqn_torso_apply


HIDDEN = (256, 256)


def actor_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    return mlp_init(key, [obs_dim, *hidden, act_dim])


def actor_apply(params, obs):
    return jnp.tanh(mlp_apply(params, obs))


def gaussian_actor_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    return mlp_init(key, [obs_dim, *hidden, 2 * act_dim])


def gaussian_actor_apply(params, obs):
    out = mlp_apply(params, obs)
    mean, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, -20.0, 2.0)
    return mean, log_std


def sample_squashed(key, mean, log_std):
    """Tanh-squashed gaussian sample + log-prob (SAC)."""
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    pre = mean + std * eps
    act = jnp.tanh(pre)
    logp = jnp.sum(
        -0.5 * (eps ** 2 + 2 * log_std + jnp.log(2 * jnp.pi))
        - jnp.log(jnp.maximum(1 - act ** 2, 1e-6)), axis=-1)
    return act, logp


def logits_init(key, obs_dim: int, num_actions: int, hidden=HIDDEN):
    """Categorical-policy head (raw logits; apply with ``mlp_apply``)."""
    return mlp_init(key, [obs_dim, *hidden, num_actions])


def value_init(key, obs_dim: int, hidden=HIDDEN):
    """State-value head V(s) (PPO's critic — no action input)."""
    return mlp_init(key, [obs_dim, *hidden, 1])


def value_apply(params, obs):
    return mlp_apply(params, obs)[..., 0]


def gaussian_log_prob(mean, log_std, actions):
    """Diagonal-gaussian log-density of ``actions`` (sum over act dims)."""
    var = jnp.exp(2.0 * log_std)
    return jnp.sum(-0.5 * ((actions - mean) ** 2 / var + 2.0 * log_std
                           + jnp.log(2.0 * jnp.pi)), axis=-1)


def gaussian_entropy(log_std):
    return jnp.sum(log_std + 0.5 * jnp.log(2.0 * jnp.pi * jnp.e), axis=-1)


def categorical_log_prob(logits, actions):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(
        logp, actions[..., None].astype(jnp.int32), axis=-1)[..., 0]


def categorical_entropy(logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def critic_init(key, obs_dim: int, act_dim: int, hidden=HIDDEN):
    k1, k2 = jax.random.split(key)
    return {"q1": mlp_init(k1, [obs_dim + act_dim, *hidden, 1]),
            "q2": mlp_init(k2, [obs_dim + act_dim, *hidden, 1])}


def critic_apply(params, obs, act):
    x = jnp.concatenate([obs, act], axis=-1)
    return (mlp_apply(params["q1"], x)[..., 0],
            mlp_apply(params["q2"], x)[..., 0])


def q_net_init(key, obs_dim: int, num_actions: int, hidden=HIDDEN,
               conv_torso: bool = False):
    if conv_torso:  # Atari-style: 84x84x4 frames
        k1, k2 = jax.random.split(key)
        return {"torso": dqn_torso_init(k1),
                "head": mlp_init(k2, [3136, 512, num_actions])}
    return {"head": mlp_init(key, [obs_dim, *hidden, num_actions])}


def q_net_apply(params, obs):
    if "torso" in params:
        obs = dqn_torso_apply(params["torso"], obs)
    return mlp_apply(params["head"], obs)
