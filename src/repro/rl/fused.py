"""Shared machinery for the population-level (fused-optimizer) updates.

Every rl module exposes ``make_population_update(...)`` building an update
with the POPULATION-level signature

    update(pop_state, batch, hypers) -> (pop_state, metrics)

where ``pop_state`` is the member-stacked state (leaves ``(N, ...)``),
``batch`` leaves are ``(N, B, ...)`` and hypers is a dict of ``(N,)``
vectors (or None).  The decomposition is the same as the stock per-member
``update`` under ``vmap`` — per-member gradients, per-member gates — except
the optimizer is HOISTED out of the member step into one
``repro.optim.population_adam`` application over the whole population's
flattened ``(N, P)`` parameter matrix (the ``kernels/pop_adam`` Pallas
kernel on TPU, its elementwise-identical jnp fallback elsewhere).

This module holds the pieces all four algorithms share: broadcasting
default hypers to per-member ``(N,)`` vectors, the member-masked tree
select used for gated components (TD3's delayed actor, DQN's target sync),
and the per-member key split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pop_hypers(defaults: dict, hypers, n: int) -> dict:
    """Merge ``defaults`` with the per-member ``hypers`` dict, broadcasting
    every entry to an ``(N,)`` float32 vector so one population-level
    expression serves members with different values."""
    h = {k: jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n,))
         for k, v in defaults.items()}
    if hypers:
        for k, v in hypers.items():
            h[k] = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n,))
    return h


def pop_select(mask, new, old):
    """Per-member tree select: leaves of ``new``/``old`` are ``(N, ...)``,
    ``mask`` is ``(N,)`` bool — member i keeps ``new`` iff ``mask[i]``."""
    return jax.tree.map(
        lambda a, b: jnp.where(mask.reshape(mask.shape + (1,) * (a.ndim - 1)),
                               a, b), new, old)


def pop_split(keys, num: int = 2):
    """``jax.random.split`` per member: (N, 2) keys -> ``num`` arrays of
    (N, 2) keys, matching the stock update's in-step split exactly."""
    ks = jax.vmap(lambda k: jax.random.split(k, num))(keys)
    return tuple(ks[:, i] for i in range(num))
