from repro.rl import td3, sac, dqn  # noqa: F401
