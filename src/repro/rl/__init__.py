from repro.rl import td3, sac, dqn, ppo  # noqa: F401
from repro.rl.registry import (  # noqa: F401
    ALGOS, AlgoSpec, get_algo, make_agent,
)
