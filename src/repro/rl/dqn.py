"""DQN (Mnih et al., 2013) — population-vectorizable.

Dynamic hyperparameters: lr, discount, epsilon (exploration).
``conv_torso=True`` gives the Atari CNN parametrization from the paper's
Fig. 2 DQN study; the MLP variant drives the pure-JAX cartpole env.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets

DEFAULT_HYPERS = {"lr": 1e-4, "discount": 0.99, "epsilon": 0.05}
TARGET_UPDATE_EVERY = 100

_opt_init, _opt_update = adam(1e-4)


class DQNState(NamedTuple):
    q: Any
    target_q: Any
    opt: Any
    step: jnp.ndarray
    key: jnp.ndarray


def init(key, obs_dim: int, num_actions: int, conv_torso: bool = False,
         hidden=nets.HIDDEN) -> DQNState:
    kq, kk = jax.random.split(key)
    q = nets.q_net_init(kq, obs_dim, num_actions, hidden=hidden,
                        conv_torso=conv_torso)
    return DQNState(q=q, target_q=jax.tree.map(jnp.copy, q),
                    opt=_opt_init(q), step=jnp.zeros((), jnp.int32), key=kk)


def policy(q_params, obs, key=None, epsilon: float = 0.05):
    qvals = nets.q_net_apply(q_params, obs)
    greedy = jnp.argmax(qvals, axis=-1)
    if key is None:
        return greedy
    kr, ka = jax.random.split(key)
    rand = jax.random.randint(ka, greedy.shape, 0, qvals.shape[-1])
    return jnp.where(jax.random.uniform(kr, greedy.shape) < epsilon, rand, greedy)


def update(state: DQNState, batch, hypers=None) -> tuple[DQNState, dict]:
    h = dict(DEFAULT_HYPERS)
    if hypers:
        h.update(hypers)
    key, _ = jax.random.split(state.key)

    def loss_fn(q):
        qvals = nets.q_net_apply(q, batch["obs"])
        qa = jnp.take_along_axis(qvals, batch["action"][..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        tq = nets.q_net_apply(state.target_q, batch["next_obs"])
        target = batch["reward"] + h["discount"] * (1 - batch["done"]) * \
            jnp.max(tq, axis=-1)
        return jnp.mean((qa - jax.lax.stop_gradient(target)) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(state.q)
    upd, opt = _opt_update(grads, state.opt, lr_override=h["lr"])
    q = apply_updates(state.q, upd)
    step = state.step + 1
    sync = (step % TARGET_UPDATE_EVERY) == 0
    target_q = jax.tree.map(lambda t, o: jnp.where(sync, o, t), state.target_q, q)
    return DQNState(q=q, target_q=target_q, opt=opt, step=step, key=key), \
        {"loss": loss}


def _member_loss(q, target_q, batch, h):
    """Stock TD loss with explicit args (vmappable per member)."""
    qvals = nets.q_net_apply(q, batch["obs"])
    qa = jnp.take_along_axis(qvals, batch["action"][..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    tq = nets.q_net_apply(target_q, batch["next_obs"])
    target = batch["reward"] + h["discount"] * (1 - batch["done"]) * \
        jnp.max(tq, axis=-1)
    return jnp.mean((qa - jax.lax.stop_gradient(target)) ** 2)


def make_population_update(*, fused_linear: bool = False, fused=None):
    """Population-level DQN update: per-member TD gradients with the Adam
    application hoisted into ``repro.optim.population_adam`` and the target
    sync expressed as a member-masked select (see ``repro.rl.fused``)."""
    from repro.optim.pop_adam import population_adam
    from repro.rl.fused import pop_hypers, pop_select, pop_split
    _, pa = population_adam(1e-4, fused=fused)

    def pop_loss(q, target_q, batch, h):
        qvals = nets.pop_q_net_apply(q, batch["obs"])
        qa = jnp.take_along_axis(
            qvals, batch["action"][..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        tq = nets.pop_q_net_apply(target_q, batch["next_obs"])
        target = batch["reward"] + h["discount"][:, None] * \
            (1 - batch["done"]) * jnp.max(tq, axis=-1)
        per = jnp.mean((qa - jax.lax.stop_gradient(target)) ** 2, axis=1)
        return jnp.sum(per), per

    def update(state: DQNState, batch, hypers=None):
        n = state.step.shape[0]
        h = pop_hypers(DEFAULT_HYPERS, hypers, n)
        key, _ = pop_split(state.key)

        if fused_linear:
            (_, loss), grads = jax.value_and_grad(pop_loss, has_aux=True)(
                state.q, state.target_q, batch, h)
        else:
            loss, grads = jax.vmap(jax.value_and_grad(_member_loss))(
                state.q, state.target_q, batch, h)
        q, opt = pa(state.q, grads, state.opt, lr_override=h["lr"])

        step = state.step + 1
        sync = (step % TARGET_UPDATE_EVERY) == 0
        target_q = pop_select(sync, q, state.target_q)
        return DQNState(q=q, target_q=target_q, opt=opt, step=step,
                        key=key), {"loss": loss}

    return update
