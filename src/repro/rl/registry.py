"""Algorithm registry: ``--algo td3|sac|dqn|ppo`` as data, not if/elif.

Each entry bundles what a launcher needs to train the algorithm through
the unified ``repro.pop`` + ``repro.rollout`` stack: an agent factory
(env-spec aware, so discrete/continuous mismatches fail loudly), the
action-space constraint, and a sensible PBT hyper-space (paper §B.1 style
ranges).  ``repro.launch.train`` and the examples resolve names through
:func:`get_algo` / :func:`make_agent`, so adding an algorithm is one
registry entry — no call-site chains to keep in sync.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.configs.base import HyperSpace


@dataclass(frozen=True)
class AlgoSpec:
    name: str
    make_agent: Callable            # (env_spec, **kw) -> repro.pop.Agent
    actions: str                    # "continuous" | "discrete" | "both"
    hyper_space: HyperSpace
    experience_kind: str


def _make_td3(spec, **kw):
    from repro.pop import ModuleAgent
    from repro.rl import td3
    return ModuleAgent(td3, spec.obs_dim, spec.act_dim, **kw)


def _make_sac(spec, **kw):
    from repro.pop import ModuleAgent
    from repro.rl import sac
    return ModuleAgent(sac, spec.obs_dim, spec.act_dim, **kw)


def _make_dqn(spec, **kw):
    from repro.pop import ModuleAgent
    from repro.rl import dqn
    return ModuleAgent(dqn, spec.obs_dim, spec.act_dim, **kw)


def _make_ppo(spec, **kw):
    from repro.pop import PPOAgent
    return PPOAgent(spec.obs_dim, spec.act_dim, discrete=spec.discrete, **kw)


ALGOS = {
    "td3": AlgoSpec(
        "td3", _make_td3, "continuous",
        HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),
                                ("critic_lr", 3e-5, 3e-3)),
                   uniform=(("policy_freq", 0.2, 1.0), ("noise", 0.0, 1.0),
                            ("explore_noise", 0.0, 1.0),
                            ("discount", 0.9, 1.0))),
        "replay"),
    "sac": AlgoSpec(
        "sac", _make_sac, "continuous",
        HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),
                                ("critic_lr", 3e-5, 3e-3),
                                ("alpha", 0.01, 1.0)),
                   uniform=(("discount", 0.9, 1.0),)),
        "replay"),
    "dqn": AlgoSpec(
        "dqn", _make_dqn, "discrete",
        HyperSpace(log_uniform=(("lr", 1e-5, 1e-3),),
                   uniform=(("epsilon", 0.01, 0.3), ("discount", 0.9, 1.0))),
        "replay"),
    "ppo": AlgoSpec(
        "ppo", _make_ppo, "both",
        HyperSpace(log_uniform=(("lr", 1e-5, 1e-3),),
                   uniform=(("clip_eps", 0.1, 0.3),
                            ("entropy_coef", 0.0, 0.03),
                            ("gae_lambda", 0.9, 1.0),
                            ("discount", 0.9, 1.0))),
        "trajectory"),
}


def get_algo(name: str) -> AlgoSpec:
    spec = ALGOS.get(name)
    if spec is None:
        raise ValueError(f"unknown algorithm {name!r}; registered: "
                         f"{sorted(ALGOS)}")
    return spec


def make_agent(name: str, env_spec, **kw):
    """Build the registered agent for an env, validating the action space."""
    algo = get_algo(name)
    if algo.actions == "continuous" and env_spec.discrete:
        raise ValueError(f"{name} needs a continuous action space but "
                         f"env {env_spec.name!r} is discrete")
    if algo.actions == "discrete" and not env_spec.discrete:
        raise ValueError(f"{name} needs a discrete action space but "
                         f"env {env_spec.name!r} is continuous")
    return algo.make_agent(env_spec, **kw)
