"""SAC (Haarnoja et al., 2018) with learned temperature — population-ready.

PBT-tunable dynamic hyperparameters (paper §B.1): actor_lr, critic_lr,
alpha_lr, target_entropy scale, reward_scale, discount.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets

DEFAULT_HYPERS = {
    "actor_lr": 3e-4, "critic_lr": 3e-4, "alpha_lr": 3e-4,
    "target_entropy_scale": 1.0, "reward_scale": 1.0, "discount": 0.99,
}
TAU = 0.005

_opt_init, _opt_update = adam(3e-4)


class SACState(NamedTuple):
    actor: Any
    critic: Any
    target_critic: Any
    log_alpha: jnp.ndarray
    actor_opt: Any
    critic_opt: Any
    alpha_opt: Any
    step: jnp.ndarray
    key: jnp.ndarray


def init(key, obs_dim: int, act_dim: int,
         hidden=nets.HIDDEN) -> SACState:
    ka, kc, kk = jax.random.split(key, 3)
    actor = nets.gaussian_actor_init(ka, obs_dim, act_dim, hidden=hidden)
    critic = nets.critic_init(kc, obs_dim, act_dim, hidden=hidden)
    log_alpha = jnp.zeros(())
    return SACState(actor=actor, critic=critic,
                    target_critic=jax.tree.map(jnp.copy, critic),
                    log_alpha=log_alpha,
                    actor_opt=_opt_init(actor), critic_opt=_opt_init(critic),
                    alpha_opt=_opt_init(log_alpha),
                    step=jnp.zeros((), jnp.int32), key=kk)


def policy(actor_params, obs, key=None):
    mean, log_std = nets.gaussian_actor_apply(actor_params, obs)
    if key is None:
        return jnp.tanh(mean)
    act, _ = nets.sample_squashed(key, mean, log_std)
    return act


def update(state: SACState, batch, hypers=None) -> tuple[SACState, dict]:
    h = dict(DEFAULT_HYPERS)
    if hypers:
        h.update(hypers)
    act_dim = batch["action"].shape[-1]
    target_entropy = -h["target_entropy_scale"] * act_dim
    key, k1, k2 = jax.random.split(state.key, 3)
    alpha = jnp.exp(state.log_alpha)
    reward = batch["reward"] * h["reward_scale"]

    # critic
    def critic_loss(critic):
        mean, log_std = nets.gaussian_actor_apply(state.actor, batch["next_obs"])
        next_a, next_logp = nets.sample_squashed(k1, mean, log_std)
        tq1, tq2 = nets.critic_apply(state.target_critic, batch["next_obs"], next_a)
        target = reward + h["discount"] * (1 - batch["done"]) * (
            jnp.minimum(tq1, tq2) - alpha * next_logp)
        q1, q2 = nets.critic_apply(critic, batch["obs"], batch["action"])
        target = jax.lax.stop_gradient(target)
        return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2)

    closs, cgrads = jax.value_and_grad(critic_loss)(state.critic)
    cupd, critic_opt = _opt_update(cgrads, state.critic_opt,
                                   lr_override=h["critic_lr"])
    critic = apply_updates(state.critic, cupd)

    # actor
    def actor_loss(actor):
        mean, log_std = nets.gaussian_actor_apply(actor, batch["obs"])
        a, logp = nets.sample_squashed(k2, mean, log_std)
        q1, q2 = nets.critic_apply(critic, batch["obs"], a)
        return jnp.mean(alpha * logp - jnp.minimum(q1, q2)), logp

    (aloss, logp), agrads = jax.value_and_grad(actor_loss, has_aux=True)(state.actor)
    aupd, actor_opt = _opt_update(agrads, state.actor_opt,
                                  lr_override=h["actor_lr"])
    actor = apply_updates(state.actor, aupd)

    # temperature
    def alpha_loss(log_alpha):
        return -jnp.mean(jnp.exp(log_alpha) *
                         jax.lax.stop_gradient(logp + target_entropy))

    l_loss, lgrad = jax.value_and_grad(alpha_loss)(state.log_alpha)
    lupd, alpha_opt = _opt_update(lgrad, state.alpha_opt,
                                  lr_override=h["alpha_lr"])
    log_alpha = state.log_alpha + lupd

    target_critic = jax.tree.map(lambda t, o: (1 - TAU) * t + TAU * o,
                                 state.target_critic, critic)
    new_state = SACState(actor=actor, critic=critic,
                         target_critic=target_critic, log_alpha=log_alpha,
                         actor_opt=actor_opt, critic_opt=critic_opt,
                         alpha_opt=alpha_opt, step=state.step + 1, key=key)
    return new_state, {"critic_loss": closs, "actor_loss": aloss,
                       "alpha": jnp.exp(log_alpha)}


def _member_critic_loss(critic, actor, target_critic, alpha, batch, k1, h):
    """Stock critic loss with explicit args (vmappable per member)."""
    mean, log_std = nets.gaussian_actor_apply(actor, batch["next_obs"])
    next_a, next_logp = nets.sample_squashed(k1, mean, log_std)
    tq1, tq2 = nets.critic_apply(target_critic, batch["next_obs"], next_a)
    target = batch["reward"] * h["reward_scale"] + \
        h["discount"] * (1 - batch["done"]) * (
            jnp.minimum(tq1, tq2) - alpha * next_logp)
    q1, q2 = nets.critic_apply(critic, batch["obs"], batch["action"])
    target = jax.lax.stop_gradient(target)
    return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2)


def _member_actor_loss(actor, critic, alpha, batch, k2):
    mean, log_std = nets.gaussian_actor_apply(actor, batch["obs"])
    a, logp = nets.sample_squashed(k2, mean, log_std)
    q1, q2 = nets.critic_apply(critic, batch["obs"], a)
    return jnp.mean(alpha * logp - jnp.minimum(q1, q2)), logp


def _squash(eps, mean, log_std):
    """``sample_squashed`` with the normal draw supplied (population path:
    eps is drawn per member outside, the math stays elementwise)."""
    std = jnp.exp(log_std)
    pre = mean + std * eps
    act = jnp.tanh(pre)
    logp = jnp.sum(
        -0.5 * (eps ** 2 + 2 * log_std + jnp.log(2 * jnp.pi))
        - jnp.log(jnp.maximum(1 - act ** 2, 1e-6)), axis=-1)
    return act, logp


def make_population_update(*, fused_linear: bool = False, fused=None):
    """Population-level SAC: per-member gradients for critic / actor /
    temperature with all three Adam applications hoisted into
    ``repro.optim.population_adam`` (see ``repro.rl.fused``)."""
    from repro.optim.pop_adam import population_adam
    from repro.rl.fused import pop_hypers, pop_split
    _, pa = population_adam(3e-4, fused=fused)

    def pop_critic_loss(critic, actor, target_critic, alpha, batch, eps, h):
        mean, log_std = nets.pop_gaussian_actor_apply(actor,
                                                      batch["next_obs"])
        next_a, next_logp = _squash(eps, mean, log_std)
        tq1, tq2 = nets.pop_critic_apply(target_critic, batch["next_obs"],
                                         next_a)
        target = batch["reward"] * h["reward_scale"][:, None] + \
            h["discount"][:, None] * (1 - batch["done"]) * (
                jnp.minimum(tq1, tq2) - alpha[:, None] * next_logp)
        q1, q2 = nets.pop_critic_apply(critic, batch["obs"], batch["action"])
        target = jax.lax.stop_gradient(target)
        per = jnp.mean((q1 - target) ** 2, axis=1) + \
            jnp.mean((q2 - target) ** 2, axis=1)
        return jnp.sum(per), per

    def pop_actor_loss(actor, critic, alpha, batch, eps):
        mean, log_std = nets.pop_gaussian_actor_apply(actor, batch["obs"])
        a, logp = _squash(eps, mean, log_std)
        q1, q2 = nets.pop_critic_apply(critic, batch["obs"], a)
        per = jnp.mean(alpha[:, None] * logp - jnp.minimum(q1, q2), axis=1)
        return jnp.sum(per), (per, logp)

    def update(state: SACState, batch, hypers=None):
        n = state.step.shape[0]
        h = pop_hypers(DEFAULT_HYPERS, hypers, n)
        act_dim = batch["action"].shape[-1]
        target_entropy = -h["target_entropy_scale"] * act_dim    # (N,)
        key, k1, k2 = pop_split(state.key, 3)
        alpha = jnp.exp(state.log_alpha)                          # (N,)

        if fused_linear:
            draw = lambda ks: jax.vmap(
                lambda k: jax.random.normal(k, batch["action"].shape[1:]))(ks)
            (_, closs), cgrads = jax.value_and_grad(
                pop_critic_loss, has_aux=True)(
                    state.critic, state.actor, state.target_critic, alpha,
                    batch, draw(k1), h)
        else:
            closs, cgrads = jax.vmap(jax.value_and_grad(_member_critic_loss))(
                state.critic, state.actor, state.target_critic, alpha,
                batch, k1, h)
        critic, critic_opt = pa(state.critic, cgrads, state.critic_opt,
                                lr_override=h["critic_lr"])

        if fused_linear:
            (_, (aloss, logp)), agrads = jax.value_and_grad(
                pop_actor_loss, has_aux=True)(
                    state.actor, critic, alpha, batch, draw(k2))
        else:
            (aloss, logp), agrads = jax.vmap(jax.value_and_grad(
                _member_actor_loss, has_aux=True))(
                    state.actor, critic, alpha, batch, k2)
        actor, actor_opt = pa(state.actor, agrads, state.actor_opt,
                              lr_override=h["actor_lr"])

        def alpha_loss_m(log_alpha, logp_m, te):
            return -jnp.mean(jnp.exp(log_alpha) *
                             jax.lax.stop_gradient(logp_m + te))

        _, lgrad = jax.vmap(jax.value_and_grad(alpha_loss_m))(
            state.log_alpha, logp, target_entropy)
        log_alpha, alpha_opt = pa(state.log_alpha, lgrad, state.alpha_opt,
                                  lr_override=h["alpha_lr"])

        target_critic = jax.tree.map(lambda t, o: (1 - TAU) * t + TAU * o,
                                     state.target_critic, critic)
        new_state = SACState(actor=actor, critic=critic,
                             target_critic=target_critic, log_alpha=log_alpha,
                             actor_opt=actor_opt, critic_opt=critic_opt,
                             alpha_opt=alpha_opt, step=state.step + 1,
                             key=key)
        return new_state, {"critic_loss": closs, "actor_loss": aloss,
                           "alpha": jnp.exp(log_alpha)}

    return update
