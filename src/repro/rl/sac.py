"""SAC (Haarnoja et al., 2018) with learned temperature — population-ready.

PBT-tunable dynamic hyperparameters (paper §B.1): actor_lr, critic_lr,
alpha_lr, target_entropy scale, reward_scale, discount.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets

DEFAULT_HYPERS = {
    "actor_lr": 3e-4, "critic_lr": 3e-4, "alpha_lr": 3e-4,
    "target_entropy_scale": 1.0, "reward_scale": 1.0, "discount": 0.99,
}
TAU = 0.005

_opt_init, _opt_update = adam(3e-4)


class SACState(NamedTuple):
    actor: Any
    critic: Any
    target_critic: Any
    log_alpha: jnp.ndarray
    actor_opt: Any
    critic_opt: Any
    alpha_opt: Any
    step: jnp.ndarray
    key: jnp.ndarray


def init(key, obs_dim: int, act_dim: int,
         hidden=nets.HIDDEN) -> SACState:
    ka, kc, kk = jax.random.split(key, 3)
    actor = nets.gaussian_actor_init(ka, obs_dim, act_dim, hidden=hidden)
    critic = nets.critic_init(kc, obs_dim, act_dim, hidden=hidden)
    log_alpha = jnp.zeros(())
    return SACState(actor=actor, critic=critic,
                    target_critic=jax.tree.map(jnp.copy, critic),
                    log_alpha=log_alpha,
                    actor_opt=_opt_init(actor), critic_opt=_opt_init(critic),
                    alpha_opt=_opt_init(log_alpha),
                    step=jnp.zeros((), jnp.int32), key=kk)


def policy(actor_params, obs, key=None):
    mean, log_std = nets.gaussian_actor_apply(actor_params, obs)
    if key is None:
        return jnp.tanh(mean)
    act, _ = nets.sample_squashed(key, mean, log_std)
    return act


def update(state: SACState, batch, hypers=None) -> tuple[SACState, dict]:
    h = dict(DEFAULT_HYPERS)
    if hypers:
        h.update(hypers)
    act_dim = batch["action"].shape[-1]
    target_entropy = -h["target_entropy_scale"] * act_dim
    key, k1, k2 = jax.random.split(state.key, 3)
    alpha = jnp.exp(state.log_alpha)
    reward = batch["reward"] * h["reward_scale"]

    # critic
    def critic_loss(critic):
        mean, log_std = nets.gaussian_actor_apply(state.actor, batch["next_obs"])
        next_a, next_logp = nets.sample_squashed(k1, mean, log_std)
        tq1, tq2 = nets.critic_apply(state.target_critic, batch["next_obs"], next_a)
        target = reward + h["discount"] * (1 - batch["done"]) * (
            jnp.minimum(tq1, tq2) - alpha * next_logp)
        q1, q2 = nets.critic_apply(critic, batch["obs"], batch["action"])
        target = jax.lax.stop_gradient(target)
        return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2)

    closs, cgrads = jax.value_and_grad(critic_loss)(state.critic)
    cupd, critic_opt = _opt_update(cgrads, state.critic_opt,
                                   lr_override=h["critic_lr"])
    critic = apply_updates(state.critic, cupd)

    # actor
    def actor_loss(actor):
        mean, log_std = nets.gaussian_actor_apply(actor, batch["obs"])
        a, logp = nets.sample_squashed(k2, mean, log_std)
        q1, q2 = nets.critic_apply(critic, batch["obs"], a)
        return jnp.mean(alpha * logp - jnp.minimum(q1, q2)), logp

    (aloss, logp), agrads = jax.value_and_grad(actor_loss, has_aux=True)(state.actor)
    aupd, actor_opt = _opt_update(agrads, state.actor_opt,
                                  lr_override=h["actor_lr"])
    actor = apply_updates(state.actor, aupd)

    # temperature
    def alpha_loss(log_alpha):
        return -jnp.mean(jnp.exp(log_alpha) *
                         jax.lax.stop_gradient(logp + target_entropy))

    l_loss, lgrad = jax.value_and_grad(alpha_loss)(state.log_alpha)
    lupd, alpha_opt = _opt_update(lgrad, state.alpha_opt,
                                  lr_override=h["alpha_lr"])
    log_alpha = state.log_alpha + lupd

    target_critic = jax.tree.map(lambda t, o: (1 - TAU) * t + TAU * o,
                                 state.target_critic, critic)
    new_state = SACState(actor=actor, critic=critic,
                         target_critic=target_critic, log_alpha=log_alpha,
                         actor_opt=actor_opt, critic_opt=critic_opt,
                         alpha_opt=alpha_opt, step=state.step + 1, key=key)
    return new_state, {"critic_loss": closs, "actor_loss": aloss,
                       "alpha": jnp.exp(log_alpha)}
