"""TD3 (Fujimoto et al., 2018) — functional, population-vectorizable.

Every hyperparameter the paper's PBT study tunes (§B.1) is a *dynamic* input
(the ``hypers`` dict), so one compiled update step serves all members with
their own values under ``vmap``:
    actor_lr, critic_lr, policy_freq (0.2..1), noise, discount.
The delayed-policy-update trick is expressed as the fractional-frequency
gate ``floor(step*f) > floor((step-1)*f)`` which is vmappable (no python
control flow).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets


DEFAULT_HYPERS = {
    "actor_lr": 3e-4, "critic_lr": 3e-4, "policy_freq": 0.5,
    "noise": 0.2, "discount": 0.99,
}
NOISE_CLIP = 0.5
TAU = 0.005

_opt_init, _opt_update = adam(3e-4)


class TD3State(NamedTuple):
    actor: Any
    critic: Any
    target_actor: Any
    target_critic: Any
    actor_opt: Any
    critic_opt: Any
    step: jnp.ndarray
    key: jnp.ndarray


def init(key, obs_dim: int, act_dim: int,
         hidden=nets.HIDDEN) -> TD3State:
    ka, kc, kk = jax.random.split(key, 3)
    actor = nets.actor_init(ka, obs_dim, act_dim, hidden=hidden)
    critic = nets.critic_init(kc, obs_dim, act_dim, hidden=hidden)
    return TD3State(
        actor=actor, critic=critic,
        target_actor=jax.tree.map(jnp.copy, actor),
        target_critic=jax.tree.map(jnp.copy, critic),
        actor_opt=_opt_init(actor), critic_opt=_opt_init(critic),
        step=jnp.zeros((), jnp.int32), key=kk)


def policy(actor_params, obs, key=None, exploration_noise: float = 0.1):
    a = nets.actor_apply(actor_params, obs)
    if key is not None:
        a = jnp.clip(a + exploration_noise * jax.random.normal(key, a.shape),
                     -1.0, 1.0)
    return a


def critic_loss_fn(critic, target_actor, target_critic, batch, key, hypers):
    noise = jnp.clip(
        hypers["noise"] * jax.random.normal(key, batch["action"].shape),
        -NOISE_CLIP, NOISE_CLIP)
    next_a = jnp.clip(nets.actor_apply(target_actor, batch["next_obs"]) + noise,
                      -1.0, 1.0)
    tq1, tq2 = nets.critic_apply(target_critic, batch["next_obs"], next_a)
    target = batch["reward"] + hypers["discount"] * (1 - batch["done"]) * \
        jnp.minimum(tq1, tq2)
    q1, q2 = nets.critic_apply(critic, batch["obs"], batch["action"])
    target = jax.lax.stop_gradient(target)
    return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2)


def actor_loss_fn(actor, critic, batch):
    a = nets.actor_apply(actor, batch["obs"])
    q1, _ = nets.critic_apply(critic, batch["obs"], a)
    return -jnp.mean(q1)


def _soft_update(target, online, tau=TAU):
    return jax.tree.map(lambda t, o: (1 - tau) * t + tau * o, target, online)


def update(state: TD3State, batch, hypers=None) -> tuple[TD3State, dict]:
    """One TD3 update step (critic always; actor at frequency policy_freq)."""
    h = dict(DEFAULT_HYPERS)
    if hypers:
        h.update(hypers)
    key, kc = jax.random.split(state.key)

    closs, cgrads = jax.value_and_grad(critic_loss_fn)(
        state.critic, state.target_actor, state.target_critic, batch, kc, h)
    cupd, critic_opt = _opt_update(cgrads, state.critic_opt,
                                   lr_override=h["critic_lr"])
    critic = apply_updates(state.critic, cupd)

    # fractional-frequency delayed actor update (vmappable gate)
    f = h["policy_freq"]
    step_f = state.step.astype(jnp.float32)
    do_actor = jnp.floor((step_f + 1) * f) > jnp.floor(step_f * f)

    aloss, agrads = jax.value_and_grad(actor_loss_fn)(
        state.actor, critic, batch)
    aupd, actor_opt_new = _opt_update(agrads, state.actor_opt,
                                      lr_override=h["actor_lr"])
    actor_new = apply_updates(state.actor, aupd)

    sel = lambda new, old: jax.tree.map(
        lambda n, o: jnp.where(do_actor, n, o), new, old)
    actor = sel(actor_new, state.actor)
    actor_opt = sel(actor_opt_new, state.actor_opt)
    target_actor = sel(_soft_update(state.target_actor, actor),
                       state.target_actor)
    target_critic = _soft_update(state.target_critic, critic)

    new_state = TD3State(actor=actor, critic=critic, target_actor=target_actor,
                         target_critic=target_critic, actor_opt=actor_opt,
                         critic_opt=critic_opt, step=state.step + 1, key=key)
    return new_state, {"critic_loss": closs, "actor_loss": aloss}


def make_population_update(*, fused_linear: bool = False, fused=None):
    """Population-level TD3 update: the same decomposition as
    ``vmap(update)`` but with the two Adam applications hoisted into
    ``repro.optim.population_adam`` over the whole population (the
    ``kernels/pop_adam`` path), and — with ``fused_linear`` — the loss
    forwards routed through the ``pop_matmul``-backed applies in
    ``repro.rl.networks``.  ``fused`` forwards to ``population_adam``
    (None = kernel on TPU only)."""
    from repro.optim.pop_adam import population_adam
    from repro.rl.fused import pop_hypers, pop_select, pop_split
    _, pa = population_adam(3e-4, fused=fused)

    def pop_critic_loss(critic, target_actor, target_critic, batch, eps, h):
        noise = jnp.clip(h["noise"][:, None, None] * eps,
                         -NOISE_CLIP, NOISE_CLIP)
        next_a = jnp.clip(
            nets.pop_actor_apply(target_actor, batch["next_obs"]) + noise,
            -1.0, 1.0)
        tq1, tq2 = nets.pop_critic_apply(target_critic, batch["next_obs"],
                                         next_a)
        target = batch["reward"] + h["discount"][:, None] * \
            (1 - batch["done"]) * jnp.minimum(tq1, tq2)
        q1, q2 = nets.pop_critic_apply(critic, batch["obs"], batch["action"])
        target = jax.lax.stop_gradient(target)
        per = jnp.mean((q1 - target) ** 2, axis=1) + \
            jnp.mean((q2 - target) ** 2, axis=1)
        # members are independent: the sum's gradient IS the stacked
        # per-member gradients
        return jnp.sum(per), per

    def pop_actor_loss(actor, critic, batch):
        a = nets.pop_actor_apply(actor, batch["obs"])
        q1, _ = nets.pop_critic_apply(critic, batch["obs"], a)
        per = -jnp.mean(q1, axis=1)
        return jnp.sum(per), per

    def update(state: TD3State, batch, hypers=None):
        n = state.step.shape[0]
        h = pop_hypers(DEFAULT_HYPERS, hypers, n)
        key, kc = pop_split(state.key)

        if fused_linear:
            eps = jax.vmap(
                lambda k: jax.random.normal(k, batch["action"].shape[1:]))(kc)
            (_, closs), cgrads = jax.value_and_grad(
                pop_critic_loss, has_aux=True)(
                    state.critic, state.target_actor, state.target_critic,
                    batch, eps, h)
        else:
            closs, cgrads = jax.vmap(jax.value_and_grad(critic_loss_fn))(
                state.critic, state.target_actor, state.target_critic,
                batch, kc, h)
        critic, critic_opt = pa(state.critic, cgrads, state.critic_opt,
                                lr_override=h["critic_lr"])

        f = h["policy_freq"]
        step_f = state.step.astype(jnp.float32)
        do_actor = jnp.floor((step_f + 1) * f) > jnp.floor(step_f * f)

        if fused_linear:
            (_, aloss), agrads = jax.value_and_grad(
                pop_actor_loss, has_aux=True)(state.actor, critic, batch)
        else:
            aloss, agrads = jax.vmap(jax.value_and_grad(actor_loss_fn))(
                state.actor, critic, batch)
        actor_new, actor_opt_new = pa(state.actor, agrads, state.actor_opt,
                                      lr_override=h["actor_lr"])

        actor = pop_select(do_actor, actor_new, state.actor)
        actor_opt = pop_select(do_actor, actor_opt_new, state.actor_opt)
        target_actor = pop_select(do_actor,
                                  _soft_update(state.target_actor, actor),
                                  state.target_actor)
        target_critic = _soft_update(state.target_critic, critic)

        new_state = TD3State(actor=actor, critic=critic,
                             target_actor=target_actor,
                             target_critic=target_critic, actor_opt=actor_opt,
                             critic_opt=critic_opt, step=state.step + 1,
                             key=key)
        return new_state, {"critic_loss": closs, "actor_loss": aloss}

    return update
