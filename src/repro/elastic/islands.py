"""The ``"islands"`` update backend: member groups shard_mapped over islands.

``backend="sharded"`` lets GSPMD propagate a population sharding through
the jitted vmapped update; this backend makes the paper's §5.1 topology
*explicit* instead: the population axis is split over the ``"pop"`` mesh
axis of an :class:`~repro.elastic.layout.IslandLayout` with
``repro.compat.shard_map``, so each island runs a plain vectorized update
over only its own member group and NO cross-island communication exists in
the update step at all (members are independent; the only collectives in
island training are the PBT gathers at evolve time).

Registered under ``"islands"`` in the ``repro.pop`` backend registry, so it
is the same one-line config swap as the other three:

    PopulationConfig(size=8, backend="islands")

Update numerics are identical to ``backend="vectorized"`` — the tests
assert it — because sharding only decides *where* each member's update
runs, never what it computes.
"""
from __future__ import annotations

from functools import partial

import jax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.pop.backend import register_backend


def _build_islands(agent, num_steps: int, donate: bool, mesh=None):
    if agent.population_level:
        raise ValueError("islands backend requires per-member agents (a "
                         "shared critic is replicated, not split over "
                         "islands)")
    from repro.core.vectorize import chain_steps
    batch_axis = 0 if num_steps == 1 else 1

    fused_fn = (agent.fused_update()
                if getattr(agent, "fused_adam", False) else None)
    if fused_fn is not None:
        # population-level update over the island's OWN member group: under
        # shard_map the local shard is just a smaller population, so the
        # fused pop_adam path shards over "pop" unchanged
        pop_inner = (fused_fn if num_steps == 1
                     else chain_steps(fused_fn, num_steps))

        def local(pop_state, batches, hypers):
            return pop_inner(pop_state, batches, hypers)
    else:
        inner = (agent.update if num_steps == 1
                 else chain_steps(agent.update, num_steps))

        def local(pop_state, batches, hypers):
            # ONE island's body: vectorized update over its own member group
            if hypers is None:
                return jax.vmap(lambda s, b: inner(s, b, None),
                                in_axes=(0, batch_axis))(pop_state, batches)
            return jax.vmap(inner, in_axes=(0, batch_axis, 0))(
                pop_state, batches, hypers)

    state_spec = P("pop")
    batch_spec = P("pop") if num_steps == 1 else P(None, "pop")
    compiled = {}

    def resolve_mesh(pop_state):
        if mesh is not None:
            return mesh
        from repro.elastic.layout import plan_layout
        n = jax.tree.leaves(pop_state)[0].shape[0]
        return plan_layout(len(jax.devices()), n).mesh

    def stepped(pop_state, batches, hypers=None):
        m = resolve_mesh(pop_state)
        # with a non-trivial (data, model) grid inside each island, a
        # shard_map over "pop" alone would *replicate* the intra-island
        # axes and ignore the model-sharded parameter placement; run the
        # population-level body under plain jit instead and let GSPMD
        # propagate the placed input shardings (see IslandLayout.place
        # model_rules).
        gspmd = m.devices.size > m.shape.get("pop", m.devices.size)
        key = (id(m), hypers is None, gspmd)
        fn = compiled.get(key)
        if fn is None:
            if gspmd:
                body = (partial(local, hypers=None) if hypers is None
                        else local)
            elif hypers is None:
                body = compat.shard_map(
                    lambda s, b: local(s, b, None), mesh=m,
                    in_specs=(state_spec, batch_spec),
                    out_specs=(state_spec, state_spec))
            else:
                body = compat.shard_map(
                    local, mesh=m,
                    in_specs=(state_spec, batch_spec, state_spec),
                    out_specs=(state_spec, state_spec))
            fn = compiled[key] = jax.jit(
                body, donate_argnums=(0,) if donate else ())
        if hypers is None:
            return fn(pop_state, batches)
        return fn(pop_state, batches, hypers)

    return stepped


register_backend("islands", _build_islands)
