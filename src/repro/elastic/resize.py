"""Elastic population resize: drop the worst, refill with PBT clones.

Population training is naturally elastic (the exploit/explore loop already
replaces members wholesale), so a device-count change maps onto the same
mechanics:

  * shrink — keep the ``new_size`` fittest members (the rest would have
    been exploited away at the next PBT step anyway);
  * grow   — survivors keep their own state bit-exactly, and the new slots
    are cloned from the fittest survivors round-robin, exactly what a PBT
    exploit would produce (the next explore step perturbs the copies
    apart).

Everything operates on the *stacked population pytree* convention of
``repro.core.population``: any leaf whose leading axis equals the old
population size is resized (training state, hypers, replay buffers, env
states alike); leaves without a population axis — a shared critic, CEM's
distribution state — pass through untouched.
"""
from __future__ import annotations

import jax
import numpy as np


def plan_resize(old_size: int, new_size: int, fitness=None):
    """Member index map for a resize: ``(parents, lineage)``.

    ``parents[i]`` is the OLD member whose state new member ``i`` receives;
    ``lineage[i]`` mirrors the evolution-strategy convention (the old index
    for members that keep/inherit a state).  Shrinks keep the ``new_size``
    fittest (in original order); grows keep every member in place and fill
    slots ``old_size..new_size`` with the fittest survivors round-robin.
    Without fitness, shrinks keep the first ``new_size`` members and grows
    clone from member 0 up.
    """
    if new_size < 1:
        raise ValueError(
            f"cannot resize a population to {new_size} members; training "
            f"needs at least 1 (got new_size={new_size})")
    rank = (np.argsort(np.asarray(fitness))[::-1] if fitness is not None
            else np.arange(old_size))
    if new_size <= old_size:
        parents = np.sort(rank[:new_size])
    else:
        refill = rank[np.arange(new_size - old_size) % old_size]
        parents = np.concatenate([np.arange(old_size), refill])
    return parents.astype(np.int64), parents.astype(np.int64)


def resize_tree(tree, old_size: int, parents):
    """Apply a :func:`plan_resize` index map to a stacked pytree: leaves
    with leading axis ``old_size`` are gathered by ``parents``; all other
    leaves (no population axis) are returned unchanged."""
    parents = np.asarray(parents)

    def take(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == old_size:
            return x[parents]
        return x
    return jax.tree.map(take, tree)


def shrink_population(pop_tree, fitness, new_size: int):
    """Keep the ``new_size`` fittest members (elastic population shrink).

    Returns ``(tree, keep)`` with ``keep`` the sorted surviving indices.
    ``new_size`` below 1 raises — an empty population is never a valid
    training state, and silently returning zero-length leaves used to
    poison every downstream vmap.
    """
    fitness = np.asarray(fitness)
    if not 1 <= new_size <= fitness.shape[0]:
        raise ValueError(
            f"shrink_population: new_size must be in [1, {fitness.shape[0]}]"
            f", got {new_size}")
    keep, _ = plan_resize(fitness.shape[0], new_size, fitness)
    return resize_tree(pop_tree, fitness.shape[0], keep), keep


def grow_population(pop_tree, fitness, new_size: int):
    """Grow to ``new_size`` members: survivors stay in place (bit-exact),
    new slots are PBT-style clones of the fittest.  Returns
    ``(tree, parents)``.  The old size comes from ``fitness`` (length N) —
    never from the first tree leaf, which may be a non-population leaf
    like a shared critic."""
    fitness = np.asarray(fitness)
    if fitness.ndim != 1:
        raise ValueError("grow_population needs the (N,) fitness of the "
                         "current members (it defines the old size and "
                         f"the clone ranking); got shape {fitness.shape}")
    old = fitness.shape[0]
    if new_size < old:
        raise ValueError(f"grow_population: new_size={new_size} < {old}; "
                         "use shrink_population")
    parents, _ = plan_resize(old, new_size, fitness)
    return resize_tree(pop_tree, old, parents), parents
