"""Re-layout: resume a checkpointed trainer on a DIFFERENT topology.

The fault-tolerance contract at fleet scale: when accelerators are lost
(or gained), the launcher plans a new :class:`IslandLayout` from the
surviving device count and training resumes from the latest checkpoint.
Because checkpoints are saved as host numpy (full tensors) and all
shardings are *functions* of the current layout, re-layout is: plan layout
-> restore -> resize the population -> device_put.  The population resize
is PBT mechanics (``repro.elastic.resize``): a shrink drops the least-fit
members, a grow refills with clones of the fittest — and the attached
``repro.rollout`` engine's replay buffers and env states ride along,
gathered by the same member-index map, so survivors keep their collected
experience bit-exactly.

Worked example (save on 8 devices with 8 members, resume on 4 with 6;
``donate=False`` because checkpointing reads the state)::

    pcfg = PopulationConfig(size=8, backend="islands", donate=False)
    trainer = PopTrainer(agent, pcfg, checkpoint_dir="/ckpt")
    trainer.attach_rollout(env)
    trainer.run_env_loop(100)
    trainer.save(blocking=True)
    # ... 4 of 8 accelerators survive; restart with a smaller population:
    pcfg = PopulationConfig(size=6, backend="islands", donate=False)
    trainer = PopTrainer(agent, pcfg, layout=plan_layout(4, 6),
                         checkpoint_dir="/ckpt")
    trainer.attach_rollout(env)
    step, lineage = restore_elastic(trainer)   # worst 2 members dropped
    trainer.run_env_loop(100)                  # training continues

``relayout`` is the low-level placement helper (host pytree -> mesh via
the ``repro.models.sharding`` rules) used for large single-member models.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.elastic.resize import plan_resize, resize_tree


def relayout(tree, mesh):
    """Place a host (or differently-sharded) pytree onto ``mesh`` using the
    rule-derived shardings of ``repro.models.sharding``."""
    from repro.models.sharding import param_specs
    specs = param_specs(tree, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(tree, shardings)


def restore_elastic(trainer, directory=None, *, step=None, layout=None):
    """Restore ``trainer`` (and its attached rollout engine, if any) from a
    checkpoint written by a trainer of a possibly different population size
    on a possibly different device count.

    The trainer must be freshly constructed for the NEW topology (its
    ``pcfg.size`` is the target population; its strategy/hyper space must
    match the checkpointed run so the pytree structures line up).  Returns
    ``(saved_step, lineage)`` — ``lineage[i]`` is the checkpointed member
    whose state member ``i`` now holds.  Raises ``FileNotFoundError`` when
    no checkpoint exists (callers deciding between fresh start and elastic
    resume should check ``manager.peek_extra()`` first, as
    ``launch.train --resize auto`` does).

    ``directory`` defaults to the trainer's own checkpoint dir; ``layout``
    defaults to the trainer's island layout (islands backend) or plain
    default-device placement.
    """
    from pathlib import Path

    from repro.checkpoint import CheckpointManager
    if directory is not None:
        if not Path(directory).is_dir():   # manager would mkdir a typo'd
            raise FileNotFoundError(       # path; keep restore read-only
                f"restore_elastic: checkpoint directory {directory} does "
                f"not exist")
        mgr = CheckpointManager(directory)
    elif trainer._mgr is not None:
        mgr = trainer._mgr
    else:
        raise ValueError("restore_elastic: trainer has no checkpoint_dir; "
                         "pass directory=")
    step = mgr.latest() if step is None else step
    if step is None:
        raise FileNotFoundError(
            f"restore_elastic: no checkpoint in {mgr.dir}; check "
            f"manager.peek_extra() (None when empty) before calling, or "
            f"start fresh")

    # The post-resize iteration's shapes depend only on the NEW topology
    # (the freshly-built trainer state / engine buffers), so its AOT
    # compile (jit(...).lower().compile()) can start NOW, on a background
    # thread, and overlap the restore + resize_tree data movement below —
    # re-layout and first recompile used to serialize (the PR 3 residual).
    # The join happens before returning; compiles are labeled "resize".
    join_aot = None
    if trainer._rollout is not None:
        join_aot = trainer._rollout.warm_compile_async(
            trainer.state, trainer.hypers, trainer.key)

    with trainer.telemetry.compile_scope("resize"):
        template = (trainer.state, trainer.strategy.export_state())
        (state, strat_state), extra = mgr.restore(template, step)
        hypers = None if trainer.hypers is None else \
            mgr.restore_aux("hypers", trainer.hypers, step)
        old_n = extra.get("size")
        if old_n is None:
            old_n = jax.tree.leaves(
                trainer.agent.actor_params(state))[0].shape[0]
        fitness = extra.get("fitness")
        if old_n != trainer.n and fitness is None:
            import warnings
            warnings.warn(
                "restore_elastic: checkpoint has no fitness record; "
                f"resizing {old_n} -> {trainer.n} by member index, not by "
                f"fitness", stacklevel=2)
        parents, lineage = plan_resize(old_n, trainer.n, fitness)

        state = resize_tree(state, old_n, parents)
        if hypers is not None:
            hypers = resize_tree(hypers, old_n, parents)

        place = layout.place if layout is not None else trainer._placement()
        trainer.state = place(state)
        if hypers is not None:   # keep freshly-drawn hypers when the source
            trainer.hypers = place(hypers)  # run had none (null strategy)
        if strat_state is not None:
            trainer.strategy.import_state(strat_state)

        if trainer._rollout is not None:
            rstate = mgr.restore_aux("rollout",
                                     trainer._rollout.export_state(), step)
            if rstate is not None:
                rstate = resize_tree(rstate, old_n, parents)
                trainer._rollout.import_state(place(rstate))

        if join_aot is not None:
            # total resize wall = max(compile, data movement), not the sum;
            # a compile failure is non-fatal (the engine stays on lazy jit)
            join_aot()

    trainer.step_count = extra["step"] + 1
    trainer.last_fitness = None if fitness is None else \
        np.asarray(fitness)[np.asarray(parents)]
    return extra["step"], lineage
