"""``repro.elastic`` — device topology + elasticity for population training.

The paper's §5 protocols "extend to large population sizes when provided
with a few accelerators"; this package is that claim as a subsystem:

  * :mod:`repro.elastic.layout`   — :class:`IslandLayout` /
    :func:`plan_layout`: partition the available devices into per-group
    islands (population x data x model axes) from nothing but the device
    count and the population size; :func:`plan_mesh` is the (data, model)
    grid planner for a single large member.
  * :mod:`repro.elastic.islands`  — the ``"islands"`` update backend
    (``repro.compat.shard_map`` over the ``"pop"`` mesh axis), registered
    in the ``repro.pop`` backend registry: a one-line config swap.
  * :mod:`repro.elastic.resize`   — elastic population shrink/grow (worst
    members dropped, PBT clones refill), applied uniformly to training
    state, hypers, replay buffers and env states.
  * :mod:`repro.elastic.relayout` — :func:`restore_elastic`: resume a
    ``PopTrainer`` + attached ``RolloutEngine`` from a checkpoint onto a
    different device count and/or population size.

Worked example — train 8 members across whatever devices exist, lose half
the machine, resume with 6 members on the survivors::

    from repro.configs.base import PopulationConfig
    from repro.elastic import plan_layout, restore_elastic
    from repro.envs import make
    from repro.pop import ModuleAgent, PopTrainer
    from repro.rl import td3

    env = make("pendulum")
    agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim)
    pcfg = PopulationConfig(size=8, strategy="pbt", backend="islands",
                            donate=False)
    trainer = PopTrainer(agent, pcfg, checkpoint_dir="/tmp/ckpt")
    trainer.attach_rollout(env)
    trainer.run_env_loop(50)
    trainer.save(blocking=True)

    # --- restart on a 4-device machine with 6 members --------------------
    pcfg = PopulationConfig(size=6, strategy="pbt", backend="islands",
                            donate=False)
    trainer = PopTrainer(agent, pcfg, layout=plan_layout(4, 6),
                         checkpoint_dir="/tmp/ckpt")
    trainer.attach_rollout(env)
    step, lineage = restore_elastic(trainer)  # 2 least-fit members dropped;
    trainer.run_env_loop(50)                  # buffers + env states intact
"""
from repro.elastic.layout import (  # noqa: F401
    IslandLayout, plan_layout, plan_mesh,
)
from repro.elastic.resize import (  # noqa: F401
    grow_population, plan_resize, resize_tree, shrink_population,
)
from repro.elastic.relayout import relayout, restore_elastic  # noqa: F401
from repro.elastic import islands as _islands  # noqa: F401  (registers the
#                                                "islands" update backend)
