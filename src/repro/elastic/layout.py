"""Device-topology planning: islands over the population axis.

The paper's §5.1 scaling recipe is *islands of vectorized members per
accelerator* (80 agents = 4 T4s x 20 vectorized members): the population
axis is split over an ``"pop"`` mesh axis (one group of members per
island), and whatever devices remain form the ``"data"`` / ``"model"``
axes *inside* each island for members too large to fit one accelerator.
:class:`IslandLayout` is that decomposition as a value — pure math until
``.mesh`` touches jax — and :func:`plan_layout` chooses it from nothing
but the device count and the population size:

    >>> plan_layout(num_devices=4, population=20)       # the paper's setup
    IslandLayout(devices=4, islands=4, data=1, model=1, population=20)

``plan_mesh`` is the older ingredient (largest usable (data, model) grid
for a surviving device count) kept for model-parallel re-layout of a
single large member; ``repro.elastic.relayout`` composes either with the
rule-derived shardings.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass

import numpy as np

from repro import compat


def _fit_model_axis(num_devices: int, preferred_model: int) -> int:
    """Largest width <= preferred that divides the device count, halving on
    the way down (model-parallel groups must be whole)."""
    model = max(1, preferred_model)
    while model > 1 and (num_devices % model or num_devices // model < 1):
        model //= 2
    return model


def plan_grid(num_devices: int, *, preferred_model: int = 16,
              multi_pod: bool = False):
    """The (shape, axis_names) grid ``plan_mesh`` would build — pure math,
    no jax device access, so launchers (and tests) can plan for device
    counts this host doesn't have.

    When ``preferred_model`` does not divide ``num_devices`` the width is
    halved until it does; if nothing fits, the grid degenerates to
    ``(num_devices, 1)`` — pure data parallelism, each member's model
    unsharded.  Both fallbacks warn, because a silently-shrunk model axis
    changes the memory-per-device budget the caller sized for.
    """
    model = _fit_model_axis(num_devices, preferred_model)
    if model != preferred_model:
        warnings.warn(
            f"plan_mesh: preferred_model={preferred_model} does not divide "
            f"num_devices={num_devices}; falling back to model={model}"
            + (" (pure data parallelism — model axis gone)"
               if model == 1 else ""),
            stacklevel=2)
    data = num_devices // model
    axes = ("data", "model")
    shape = (data, model)
    if multi_pod and data % 2 == 0:
        shape, axes = (2, data // 2, model), ("pod", "data", "model")
    return shape, axes


def plan_mesh(num_devices: int, *, preferred_model: int = 16,
              multi_pod: bool = False):
    """Largest usable (data, model) mesh for the surviving devices (see
    :func:`plan_grid` for the policy and the fallback warnings)."""
    shape, axes = plan_grid(num_devices, preferred_model=preferred_model,
                            multi_pod=multi_pod)
    return compat.make_mesh(shape, axes)


@dataclass(frozen=True)
class IslandLayout:
    """A partition of ``devices`` accelerators into ``islands`` member
    groups, each island an internal (data, model) grid.

    Pure math (hashable, printable, comparable — usable in configs and
    test parametrization); ``.mesh`` materializes the jax mesh with axes
    ``("pop", "data", "model")``, built lazily and cached so repeated
    access returns the *same* Mesh object (jit caches key on it).

    ``device_ids`` optionally pins the layout to an EXPLICIT device
    sequence (jax device ids, in mesh order) — the heterogeneous-host
    case, where "the first ``devices`` devices" is the wrong subset or the
    wrong order (e.g. islands must line up with NUMA/interconnect
    locality).  Still pure math until ``.mesh``: ids are just integers
    here, resolved against ``jax.devices()`` only when the mesh is built.
    """
    devices: int
    islands: int
    data: int
    model: int
    population: int
    device_ids: tuple = None

    def __post_init__(self):
        if self.islands * self.data * self.model != self.devices:
            raise ValueError(f"{self} does not tile its devices")
        if self.population % self.islands:
            raise ValueError(
                f"population={self.population} does not split into "
                f"{self.islands} whole islands")
        if self.device_ids is not None:
            ids = tuple(int(d) for d in self.device_ids)
            if len(ids) != self.devices:
                raise ValueError(
                    f"{len(ids)} explicit device ids for a layout of "
                    f"{self.devices} devices")
            if len(set(ids)) != len(ids):
                raise ValueError(f"duplicate device ids in {ids}")
            object.__setattr__(self, "device_ids", ids)

    @property
    def members_per_island(self) -> int:
        return self.population // self.islands

    @property
    def mesh(self):
        cached = _MESH_CACHE.get(self)
        if cached is None:
            cached = _MESH_CACHE[self] = _build_mesh(self)
        return cached

    def place(self, tree, *, model_rules: bool = False):
        """Place a population pytree onto the layout: leaves with a leading
        population axis are split over the ``"pop"`` mesh axis (one member
        group per island); everything else is replicated.

        ``model_rules=True`` additionally applies the ``models/sharding``
        parameter rules over each island's (data, model) sub-mesh — the
        LM-population placement, where every member is model-sharded inside
        its island so members bigger than one accelerator still fit.  Under
        ``population_mode`` the data ("F") rule axes resolve to None (the
        batch carries data parallelism), so parameter leaves land on
        ``P("pop", ..., "model")``."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = self.mesh
        n = self.population

        if model_rules and self.model > 1:
            from repro.models.sharding import (population_mode, spec_for,
                                               _path_str)

            def rule_sharding(path, leaf):
                leaf = np.asarray(leaf) if not hasattr(leaf, "ndim") else leaf
                if leaf.ndim >= 1 and leaf.shape[0] == n:
                    spec = spec_for(_path_str(path), leaf.shape[1:], mesh)
                    return NamedSharding(mesh, P("pop", *tuple(spec)))
                return NamedSharding(mesh, P())

            with population_mode():
                shardings = jax.tree_util.tree_map_with_path(
                    rule_sharding, tree)
            return jax.device_put(tree, shardings)

        def sharding(leaf):
            leaf = np.asarray(leaf) if not hasattr(leaf, "ndim") else leaf
            if leaf.ndim >= 1 and leaf.shape[0] == n:
                return NamedSharding(mesh, P("pop"))
            return NamedSharding(mesh, P())
        return jax.device_put(tree, jax.tree.map(sharding, tree))


_MESH_CACHE: dict = {}


def _build_mesh(layout: IslandLayout):
    import jax
    from jax.sharding import Mesh
    available = len(jax.devices())
    if layout.devices > available:
        raise ValueError(
            f"{layout} needs {layout.devices} devices but this process has "
            f"{available}; plan the layout for the devices that exist "
            f"(plan_layout({available}, {layout.population}), or lower "
            f"--devices)")
    shape = (layout.islands, layout.data, layout.model)
    axes = ("pop", "data", "model")
    if layout.device_ids is not None:
        # explicit placement (heterogeneous hosts): resolve ids in the
        # caller's order — islands follow the sequence, not enumeration
        by_id = {d.id: d for d in jax.devices()}
        missing = [i for i in layout.device_ids if i not in by_id]
        if missing:
            raise ValueError(
                f"explicit device ids {missing} not present in this "
                f"process (available: {sorted(by_id)})")
        devs = np.asarray([by_id[i] for i in layout.device_ids])
        return Mesh(devs.reshape(shape), axes)
    if layout.devices == available:
        return compat.make_mesh(shape, axes)
    # a layout over a device subset (--devices, or planning for survivors):
    # build the mesh explicitly from the first `devices` devices
    devs = np.asarray(jax.devices()[:layout.devices]).reshape(shape)
    return Mesh(devs, axes)


def plan_layout(num_devices: int, population: int, *,
                preferred_model: int = 1, devices=None) -> IslandLayout:
    """Choose the island decomposition for ``num_devices`` accelerators and
    a population of ``population`` members.

    Policy (the paper's §5.1 regime): give the population axis as many
    islands as divide BOTH the population and the post-model device count
    (members stay whole and islands stay balanced), then spend the
    remainder on the data axis inside each island.  ``preferred_model > 1``
    reserves a model-parallel grid per member first (large-member
    populations), falling back with a warning exactly like ``plan_mesh``.

    ``devices`` optionally pins the layout to an explicit device sequence
    (jax ``Device`` objects or integer ids, in mesh order) for
    heterogeneous hosts; it overrides ``num_devices`` (pass 0) and the
    default "first N devices" selection.
    """
    device_ids = None
    if devices is not None:
        device_ids = tuple(d.id if hasattr(d, "id") else int(d)
                           for d in devices)
        if num_devices and num_devices != len(device_ids):
            raise ValueError(
                f"num_devices={num_devices} disagrees with the "
                f"{len(device_ids)} explicit devices")
        num_devices = len(device_ids)
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    if population < 1:
        raise ValueError(f"population must be >= 1, got {population}")
    model = _fit_model_axis(num_devices, preferred_model)
    if model != preferred_model:
        warnings.warn(
            f"plan_layout: preferred_model={preferred_model} does not "
            f"divide num_devices={num_devices}; falling back to "
            f"model={model}", stacklevel=2)
    remaining = num_devices // model
    islands = math.gcd(population, remaining)
    data = remaining // islands
    return IslandLayout(devices=num_devices, islands=islands, data=data,
                        model=model, population=population,
                        device_ids=device_ids)
