"""Population-batched matmul Pallas kernel — the paper's core compute shape.

The paper's protocol turns N per-member small matmuls (too small to saturate
anything) into ONE batched launch.  On TPU the natural mapping is: the
population axis becomes the outer grid dimension, and each (member, row-tile,
col-tile) program runs an MXU-aligned (bm x bk)@(bk x bn) accumulation with
the accumulator resident in VMEM.  ``vmap``-of-matmul gives XLA the same
opportunity; this kernel makes the tiling explicit (and fuses the bias +
activation epilogue, which XLA sometimes leaves unfused for tiny matmuls).

Layout: x (N, B, K), w (N, K, M), optional bias (N, M) -> y (N, B, M).
Grid: (N, B/bm, M/bn, K/bk), K innermost so the VMEM accumulator carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, acc, *, activation: str):
    @pl.when(pl.program_id(3) == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[0], w_ref[0],
                        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _():
        y = acc[...]
        if b_ref is not None:
            y = y + b_ref[0].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "tanh":
            y = jnp.tanh(y)
        o_ref[0] = y.astype(o_ref.dtype)


def supports_shapes(bsz: int, k: int, m: int, *, bm: int = 128,
                    bn: int = 128, bk: int = 128) -> bool:
    """Whether :func:`pop_matmul` can tile ``(N,bsz,k) @ (N,k,m)``.

    Blocks clamp to the problem, so each dimension must either fit inside
    one block or be a multiple of the block.  ``repro.rl.networks`` consults
    this before routing a population-batched linear through the kernel, so
    odd hidden sizes fall back to the jnp path instead of asserting."""
    if min(bsz, k, m) <= 0:
        return False
    return all(d % min(blk, d) == 0
               for d, blk in ((bsz, bm), (m, bn), (k, bk)))


def pop_matmul(x, w, b=None, *, activation: str = "none",
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False):
    """y[n] = act(x[n] @ w[n] + b[n]).  Block sizes clamp to the problem."""
    n, bsz, k = x.shape
    m = w.shape[-1]
    bm, bn, bk = min(bm, bsz), min(bn, m), min(bk, k)
    assert bsz % bm == 0 and m % bn == 0 and k % bk == 0, \
        f"tile mismatch: {(bsz, m, k)} vs {(bm, bn, bk)}"

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda i, j, l, kk: (i, j, kk)),
        pl.BlockSpec((1, bk, bn), lambda i, j, l, kk: (i, kk, l)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l, kk: (i, l)))
        args.append(b)
        kern = functools.partial(_kernel, activation=activation)
    else:
        kern = functools.partial(
            lambda xr, wr, orf, acc, activation: _kernel(
                xr, wr, None, orf, acc, activation=activation),
            activation=activation)

    return pl.pallas_call(
        kern,
        grid=(n, bsz // bm, m // bn, k // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, j, l, kk: (i, j, l)),
        out_shape=jax.ShapeDtypeStruct((n, bsz, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
