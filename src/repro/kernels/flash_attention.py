"""Causal GQA flash attention (Pallas, TPU-target).

Streaming-softmax attention: grid (B, H, Sq/bq, Skv/bk) with the running
(max, sum, acc) statistics resident in VMEM across the innermost KV
dimension; fully-masked KV blocks (block start beyond the causal frontier)
are skipped via ``pl.when`` so causal FLOPs are ~halved vs the masked dense
product.  GQA is expressed in the BlockSpec index map (kv head = h // group)
— no KV replication in memory.

Layout: q (B, H, S, D), k/v (B, Hkv, S, D) -> out (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc, *, scale: float,
            bq: int, bk: int, causal: bool):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc[...] = jnp.zeros_like(acc)

    # causal block skip: kv block strictly after the query block's last row
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _():
        q = q_ref[0, 0]                                   # (bq, D)
        k = k_ref[0, 0]                                   # (bk, D)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = d ** -0.5 if scale is None else scale
    bq, bk = min(bq, s), min(bk, s)
    assert s % bq == 0 and s % bk == 0

    kern = functools.partial(_kernel, scale=scale, bq=bq, bk=bk, causal=causal)
    return pl.pallas_call(
        kern,
        grid=(b, h, s // bq, s // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
