"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.rwkv6 import wkv6_scan
from repro.nn.mamba2 import ssd_scan


def pop_adam_ref(params, grads, mu, nu, lr, step, *, b1=0.9, b2=0.999,
                 eps=1e-8):
    """(N,P) batched Adam with per-member lr; step is 1-based, () or (N,)."""
    g = grads.astype(jnp.float32)
    mu2 = b1 * mu + (1 - b1) * g
    nu2 = b2 * nu + (1 - b2) * g * g
    stepf = jnp.broadcast_to(step, (params.shape[0],)).astype(jnp.float32)
    c1 = (1 - b1 ** stepf)[:, None]
    c2 = (1 - b2 ** stepf)[:, None]
    upd = lr[:, None] * (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
    return params - upd, mu2, nu2


def pop_matmul_ref(x, w, b=None, *, activation: str = "none"):
    y = jnp.einsum("nbk,nkm->nbm", x, w,
                   preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b[:, None, :].astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    return y.astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q (B,H,S,D), k/v (B,Hkv,S,D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, h // hkv, s, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, h, s, d)


def wkv6_ref(r, k, v, lw, u, initial_state):
    """(B,H,S,D) layout -> matches kernels.wkv6.wkv6 outputs (fp32)."""
    to_bshd = lambda t: jnp.moveaxis(t, 1, 2)
    y, s = wkv6_scan(to_bshd(r).astype(jnp.float32),
                     to_bshd(k).astype(jnp.float32),
                     to_bshd(v).astype(jnp.float32),
                     to_bshd(lw).astype(jnp.float32),
                     u.astype(jnp.float32),
                     initial_state.astype(jnp.float32))
    return jnp.moveaxis(y, 2, 1), s


def ssd_ref(x, dt, a, b, c, initial_state):
    """(B,H,S,P) layout -> matches kernels.ssd.ssd outputs (fp32)."""
    y, s = ssd_scan(jnp.moveaxis(x, 1, 2).astype(jnp.float32),
                    jnp.moveaxis(dt, 1, 2).astype(jnp.float32),
                    a.astype(jnp.float32),
                    b.astype(jnp.float32), c.astype(jnp.float32),
                    initial_state.astype(jnp.float32))
    return jnp.moveaxis(y, 1, 2), s
