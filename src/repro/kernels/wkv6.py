"""RWKV6 WKV recurrence Pallas kernel (chunked linear-attention form).

Mirrors ``repro.nn.rwkv6.wkv6_chunked``: grid (B, H, S/chunk) with the
(Dk x Dv) state resident in VMEM across the chunk dimension (innermost), so
HBM traffic is O(S*D) instead of the O(S*D^2) a naive scan materializes.
All decay exponents are <= 0 (log-space cumsums) — no overflow.

Layout: r/k/v/lw (B, H, S, D) (pre-transposed by ops.py), u (H, D),
initial state (B, H, Dk, Dv) -> y (B, H, S, D), final state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
            state, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (CL, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (D,)

    cl_cum = jnp.cumsum(lw, axis=0)                # inclusive
    cl_prev = cl_cum - lw
    cl_tot = cl_cum[-1:]

    r_in = r * jnp.exp(cl_prev)
    k_out = k * jnp.exp(cl_tot - cl_cum)

    n = r.shape[0]
    expo = cl_prev[:, None, :] - cl_cum[None, :, :]           # (CL,CL,D)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    tril = (rows > cols)[..., None]
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, expo, 0.0)), 0.0)
    a = jnp.einsum("td,sd,tsd->ts", r, k, decay,
                   preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)
    a = a + jnp.eye(n, dtype=a.dtype) * diag[:, None]

    st = state[...]
    y = jnp.dot(r_in, st, preferred_element_type=jnp.float32) + \
        jnp.dot(a, v, preferred_element_type=jnp.float32)
    state[...] = jnp.exp(cl_tot[0])[:, None] * st + jnp.dot(
        k_out.T, v, preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _():
        sout_ref[0, 0] = state[...].astype(sout_ref.dtype)


def wkv6(r, k, v, lw, u, initial_state, *, chunk: int = 64,
         interpret: bool = False):
    """r/k/v/lw: (B, H, S, D); u: (H, D); initial_state: (B, H, D, D)."""
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0

    kern = functools.partial(_kernel, chunk=chunk)
    io_spec = pl.BlockSpec((1, 1, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0))
    y, sout = pl.pallas_call(
        kern,
        grid=(b, h, s // chunk),
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0)),
                  pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0))],
        out_specs=[io_spec,
                   pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, d, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, initial_state)
    return y, sout
