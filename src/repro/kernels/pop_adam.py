"""Fused population-Adam Pallas kernel.

The paper's protocol makes the *optimizer* update the second compute hot
spot after the matmuls: N members' Adam states update elementwise every
step.  XLA emits one elementwise chain per leaf per member; this kernel
fuses the whole thing over flattened member parameters with the
PER-MEMBER learning rate (the vmapped-hyperparameter protocol) read from
SMEM, one grid row per (member, block).

Layout: params/grads/mu/nu (N, P) fp32, lr (N,), step (N,) — the step is
per member because gated update schemes (CEM-RL's train_frac, TD3's
delayed actor) legitimately let members' optimizer clocks diverge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(step_ref, lr_ref, p_ref, g_ref, mu_ref, nu_ref,
            po_ref, muo_ref, nuo_ref, *, b1: float, b2: float, eps: float):
    g = g_ref[0].astype(jnp.float32)
    mu = b1 * mu_ref[0] + (1.0 - b1) * g
    nu = b2 * nu_ref[0] + (1.0 - b2) * g * g
    step = step_ref[0].astype(jnp.float32)
    c1 = 1.0 - b1 ** step
    c2 = 1.0 - b2 ** step
    lr = lr_ref[0]
    upd = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    po_ref[0] = p_ref[0] - upd
    muo_ref[0] = mu
    nuo_ref[0] = nu


def pop_adam(params, grads, mu, nu, lr, step, *, b1: float = 0.9,
             b2: float = 0.999, eps: float = 1e-8, block: int = 4096,
             interpret: bool = False):
    """params/grads/mu/nu: (N, P); lr: (N,); step: () or (N,) int32
    (1-based; a scalar broadcasts to every member).

    Returns (new_params, new_mu, new_nu)."""
    n, p = params.shape
    block = min(block, p)
    assert p % block == 0, (p, block)
    step = jnp.broadcast_to(step, (n,))
    kern = functools.partial(_kernel, b1=b1, b2=b2, eps=eps)
    row = pl.BlockSpec((1, block), lambda i, j: (i, j))
    member = pl.BlockSpec((1,), lambda i, j: (i,))
    out = pl.pallas_call(
        kern,
        grid=(n, p // block),
        in_specs=[member,                                      # step
                  member,                                      # lr
                  row, row, row, row],
        out_specs=[row, row, row],
        out_shape=[jax.ShapeDtypeStruct((n, p), jnp.float32)] * 3,
        interpret=interpret,
    )(step.astype(jnp.int32), lr.astype(jnp.float32),
      params, grads, mu, nu)
    return tuple(out)
