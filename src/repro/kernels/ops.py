"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to "auto": real Mosaic lowering on TPU backends,
interpret mode elsewhere (CPU validation).  The model layer calls these only
when ``cfg.use_flash`` / kernel flags are on; the dry-run lowers the pure-XLA
path so CPU cost_analysis stays well-defined (see DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pop_adam as _pa
from repro.kernels import pop_matmul as _pm
from repro.kernels import ssd as _ssd
from repro.kernels import wkv6 as _wkv


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("activation", "interpret"))
def pop_matmul(x, w, b=None, *, activation: str = "none", interpret=None):
    return _pm.pop_matmul(x, w, b, activation=activation,
                          interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def pop_adam(params, grads, mu, nu, lr, step, *, interpret=None):
    return _pa.pop_adam(params, grads, mu, nu, lr, step,
                        interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, initial_state, *, chunk: int = 64, interpret=None):
    return _wkv.wkv6(r, k, v, lw, u, initial_state, chunk=chunk,
                     interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, initial_state, *, chunk: int = 128, interpret=None):
    return _ssd.ssd(x, dt, a, b, c, initial_state, chunk=chunk,
                    interpret=_auto_interpret(interpret))


# ---------------------------------------------------------------------------
# dispatch: the model layer's single entry point into the kernel stack
#
# The nn modules (attention/rwkv6/mamba2) keep their pure-jnp reference
# implementations; these dispatchers route the hot op through the Pallas
# kernel when enabled and otherwise call the EXACT nn fallback, so flipping
# the flag never changes off-kernel numerics (tests pin the fallback path
# bitwise).  The kernels carry no custom VJPs, so "auto" (None) resolves to
# kernels only on TPU backends and callers gate them off for differentiated
# (training) forwards.
# ---------------------------------------------------------------------------


def kernels_enabled(flag=None) -> bool:
    """Resolve a tri-state kernel flag: None = auto (TPU backends only)."""
    if flag is None:
        return jax.default_backend() == "tpu"
    return bool(flag)


def attention_fn(use_kernels=None):
    """An ``attn_fn`` for :func:`repro.nn.attention.gqa_apply` routing
    full-sequence causal attention through the flash kernel — (B,S,H,D)
    nn layout transposed around the kernel's (B,H,S,D) — or None to keep
    the jnp ``sdpa_auto`` path."""
    if not kernels_enabled(use_kernels):
        return None

    def attn(q, k, v, positions, kv_positions, *, causal=True, scale=None):
        s = q.shape[1]
        if s > 128 and s % 128:  # kernel block constraint: fall back
            from repro.nn.attention import sdpa_auto
            return sdpa_auto(q, k, v, positions, kv_positions, causal=causal,
                             scale=scale)
        y = flash_attention(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                            jnp.moveaxis(v, 1, 2), causal=causal)
        return jnp.moveaxis(y, 1, 2)

    return attn


def wkv6_apply(r, k, v, lw, u, state, *, use_chunked: bool = True,
               chunk: int = 64, compute_dtype=jnp.float32, use_kernels=None):
    """RWKV6 time-mix scan on the nn layout (r/k/v/lw (B,S,H,D), u (H,D),
    state (B,H,D,D)).  Kernel when enabled and the sequence tiles evenly;
    otherwise the nn chunked/scan selection, verbatim."""
    s = r.shape[1]
    if kernels_enabled(use_kernels) and s % chunk == 0 and s > 1:
        tr = lambda t: jnp.moveaxis(t, 1, 2)
        y, new_state = wkv6(tr(r), tr(k), tr(v), tr(lw), u, state, chunk=chunk)
        return jnp.moveaxis(y, 2, 1), new_state
    from repro.nn import rwkv6 as _nn  # lazy: nn imports this module
    if use_chunked and s % chunk == 0 and s > 1:
        return _nn.wkv6_chunked(r, k, v, lw, u, state, chunk=chunk,
                                compute_dtype=compute_dtype)
    return _nn.wkv6_scan(r, k, v, lw, u, state)


def ssd_apply(x, dt, a, b, c, state, *, use_chunked: bool = True,
              chunk: int = 128, compute_dtype=jnp.float32, use_kernels=None):
    """Mamba2 SSD scan on the nn layout (x (B,S,H,P), dt (B,S,H),
    b/c (B,S,N), state (B,H,P,N)) — kernel or exact nn fallback."""
    s = x.shape[1]
    if kernels_enabled(use_kernels) and s % chunk == 0 and s > 1:
        tr = lambda t: jnp.moveaxis(t, 1, 2)
        y, new_state = ssd(tr(x), tr(dt), a, b, c, state, chunk=chunk)
        return jnp.moveaxis(y, 2, 1), new_state
    from repro.nn import mamba2 as _nn  # lazy: nn imports this module
    if use_chunked and s % chunk == 0 and s > 1:
        return _nn.ssd_chunked(x, dt, a, b, c, state, chunk=chunk,
                               compute_dtype=compute_dtype)
    return _nn.ssd_scan(x, dt, a, b, c, state)
