"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to "auto": real Mosaic lowering on TPU backends,
interpret mode elsewhere (CPU validation).  The model layer calls these only
when ``cfg.use_flash`` / kernel flags are on; the dry-run lowers the pure-XLA
path so CPU cost_analysis stays well-defined (see DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pop_adam as _pa
from repro.kernels import pop_matmul as _pm
from repro.kernels import ssd as _ssd
from repro.kernels import wkv6 as _wkv


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("activation", "interpret"))
def pop_matmul(x, w, b=None, *, activation: str = "none", interpret=None):
    return _pm.pop_matmul(x, w, b, activation=activation,
                          interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def pop_adam(params, grads, mu, nu, lr, step, *, interpret=None):
    return _pa.pop_adam(params, grads, mu, nu, lr, step,
                        interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, initial_state, *, chunk: int = 64, interpret=None):
    return _wkv.wkv6(r, k, v, lw, u, initial_state, chunk=chunk,
                     interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, initial_state, *, chunk: int = 128, interpret=None):
    return _ssd.ssd(x, dt, a, b, c, initial_state, chunk=chunk,
                    interpret=_auto_interpret(interpret))
