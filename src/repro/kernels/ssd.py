"""Mamba2 SSD chunked-scan Pallas kernel.

Mirrors ``repro.nn.mamba2.ssd_chunked``: grid (B, H, S/chunk), the (P x N)
SSM state carried in VMEM across chunks; each program computes the
intra-chunk quadratic term (segsum decay) plus the inter-chunk state
contribution, then advances the state.

Layout: x (B,H,S,P), dt (B,H,S), b/c (B,S,N) (shared across heads — the
index map ignores h), a (H,), initial state (B,H,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sout_ref,
            state, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)        # (CL, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (CL,)
    a = a_ref[0]                               # scalar
    bb = b_ref[0].astype(jnp.float32)          # (CL, N)
    cc = c_ref[0].astype(jnp.float32)          # (CL, N)

    lda = dt * a                               # (CL,), <= 0
    ca = jnp.cumsum(lda)
    ca_tot = ca[-1]

    n = x.shape[0]
    seg = ca[:, None] - ca[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    tril = rows >= cols
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)
    cb = jnp.dot(cc, bb.T, preferred_element_type=jnp.float32)  # (CLt, CLs)
    m = cb * decay * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)

    st = state[...]                            # (P, N)
    y = y + jnp.exp(ca)[:, None] * jnp.dot(
        cc, st.T, preferred_element_type=jnp.float32)
    w_out = jnp.exp(ca_tot - ca) * dt          # (CL,)
    state[...] = jnp.exp(ca_tot) * st + jnp.dot(
        (x * w_out[:, None]).T, bb, preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _():
        sout_ref[0, 0] = state[...].astype(sout_ref.dtype)


def ssd(x, dt, a, b, c, initial_state, *, chunk: int = 128,
        interpret: bool = False):
    """x: (B,H,S,P); dt: (B,H,S); a: (H,); b/c: (B,S,N); state: (B,H,P,N)."""
    bsz, h, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0

    kern = functools.partial(_kernel, chunk=chunk)
    y, sout = pl.pallas_call(
        kern,
        grid=(bsz, h, s // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bsz, h, s, p), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, initial_state)
    return y, sout
