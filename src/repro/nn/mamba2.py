"""Mamba-2 blocks (state-space duality / SSD), used by zamba2-7b.

Recurrence per head (head dim P, state dim N):
    h_t = exp(a * dt_t) h_{t-1} + dt_t * x_t B_t^T        h: (P, N)
    y_t = h_t C_t + D x_t

Two implementations:
  * ``ssd_scan``    — literal recurrence (oracle + decode step)
  * ``ssd_chunked`` — chunk-parallel SSD form (intra-chunk quadratic term +
    inter-chunk state scan).  Mirrored by the Pallas kernel in
    ``repro/kernels/ssd.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain
from repro.nn.basic import lecun_normal, normal_init, rmsnorm_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, a, b, c, state):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,N) (single group);
    state: (B,H,P,N).  Returns (y (B,S,H,P), final_state)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp            # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dt_t * a)               # (B,H)
        h = da[..., None, None] * h + jnp.einsum(
            "bh,bhp,bn->bhpn", dt_t, x_t, b_t)
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def ssd_chunked(x, dt, a, b, c, state, *, chunk: int = 128,
                compute_dtype=jnp.float32):
    """Chunk-parallel SSD, equal to ``ssd_scan`` in fp32. S % chunk == 0.

    ``compute_dtype=bf16`` runs the intra-chunk quadratic term (the HBM-
    traffic hot spot — a (B,NC,CL,CL,H) tensor) in bf16 while keeping the
    state recurrence and decay cumsums in fp32 (§Perf zamba2 iteration)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc, cl = s // chunk, chunk

    # ALL chunk math lives inside the scan body (per-chunk slices), mirroring
    # the Pallas kernel: with scan-over-layers remat, the backward pass then
    # recomputes only chunk i's work at inner step i.  (Computing the
    # intra-chunk terms vectorized over NC *outside* the scan made remat
    # replay full-sequence tensors once per inner step — a ~NC x traffic
    # blowup measured in §Perf zamba2 iteration 1.)
    cd = compute_dtype
    tril = jnp.tril(jnp.ones((cl, cl), bool))[:, :, None]

    @jax.checkpoint
    def body(h0, inp):
        xc, dtc, bc, cc = inp                        # (B,CL,H,P)/(B,CL,H)/(B,CL,N)
        lda = dtc * a                                # (B,CL,H), <= 0
        ca = jnp.cumsum(lda, axis=1)
        ca_total = ca[:, -1:]                        # (B,1,H)

        # intra-chunk: M[t,s] = exp(ca_t - ca_s) (C_t.B_s) dt_s  for s <= t
        seg = ca[:, :, None] - ca[:, None, :]        # (B,CLt,CLs,H)
        decay = jnp.exp(jnp.where(tril, seg, -jnp.inf)).astype(cd)
        cb = jnp.einsum("btm,bsm->bts", cc.astype(cd), bc.astype(cd),
                        preferred_element_type=cd)
        m = cb[..., None] * decay * dtc[:, None].astype(cd)
        y = jnp.einsum("btsh,bshp->bthp", m, xc.astype(cd),
                       preferred_element_type=jnp.float32)
        # contribution of the incoming state + state advance
        y = y + jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(ca), h0, cc)
        w_out = (jnp.exp(ca_total - ca) * dtc).astype(cd)
        h0 = jnp.exp(ca_total)[:, 0][..., None, None] * h0 + jnp.einsum(
            "bsh,bshp,bsm->bhpm", w_out, xc.astype(cd), bc.astype(cd),
            preferred_element_type=jnp.float32)
        return h0, y

    xs = (jnp.moveaxis(x.reshape(bsz, nc, cl, h, p), 1, 0),
          jnp.moveaxis(dt.reshape(bsz, nc, cl, h), 1, 0),
          jnp.moveaxis(b.reshape(bsz, nc, cl, n), 1, 0),
          jnp.moveaxis(c.reshape(bsz, nc, cl, n), 1, 0))
    final, ys = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p), final


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_block_init(key, *, d_model: int, d_state: int = 64,
                      head_dim: int = 64, expand: int = 2,
                      conv_kernel: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * d_state
    k_in, k_conv, k_out, k_dt = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * d_state + n_heads
    return {
        "in_proj": {"w": lecun_normal(k_in, (d_model, d_in_proj))},
        "conv": {"w": normal_init(k_conv, (conv_kernel, conv_ch), std=0.1),
                 "b": jnp.zeros((conv_ch,), jnp.float32)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1) midpoints
            jnp.linspace(1e-3, 1e-1, n_heads))),
        "norm": rmsnorm_init(d_inner),
        "out_proj": {"w": lecun_normal(k_out, (d_inner, d_model))},
    }


def mamba2_init_state(batch: int, d_model: int, *, d_state: int = 64,
                      head_dim: int = 64, expand: int = 2,
                      conv_kernel: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * d_state
    return {
        "ssm": jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_kernel - 1, conv_ch), dtype),
    }


def _causal_conv(w, bias, x, x_prev):
    """Depthwise causal conv. x: (B,S,C); x_prev: (B,K-1,C) left context."""
    k = w.shape[0]
    xp = jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)
    y = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),                 # (K, I=1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return y + bias.astype(x.dtype), xp[:, -(k - 1):]


def mamba2_block_apply(p, x, state, *, d_state: int = 64, head_dim: int = 64,
                       expand: int = 2, use_chunked: bool = True,
                       chunk: int = 128, compute_dtype=jnp.float32,
                       use_kernels=None):
    """x: (B,S,D); state from ``mamba2_init_state``. Returns (y, new_state)."""
    bsz, s, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim

    zxbcdt = x @ p["in_proj"]["w"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * d_state]
    dt_raw = zxbcdt[..., -n_heads:]

    xbc, conv_state = _causal_conv(p["conv"]["w"], p["conv"]["b"], xbc,
                                   state["conv"])
    xbc = jax.nn.silu(xbc)
    xh = xbc[..., :d_inner].reshape(bsz, s, n_heads, head_dim)
    b = xbc[..., d_inner:d_inner + d_state]
    c = xbc[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    # sequence-parallel -> head-parallel relayout ONCE per layer, so the
    # chunk scan never slices a model-sharded sequence axis (that put a
    # collective inside every scan step — §Perf zamba2 iteration 3).
    xh = constrain(xh, "F", None, "M", None)
    dt = constrain(dt, "F", None, "M")
    b = constrain(b, "F", None, None)
    c = constrain(c, "F", None, None)

    x32, b32, c32 = (t.astype(jnp.float32) for t in (xh, b, c))
    from repro.kernels.ops import ssd_apply  # lazy: ops falls back to us
    y, ssm = ssd_apply(x32, dt, a, b32, c32, state["ssm"],
                       use_chunked=use_chunked, chunk=chunk,
                       compute_dtype=compute_dtype, use_kernels=use_kernels)
    y = y + p["d_skip"][:, None] * x32
    y = constrain(y, "F", None, "M", None)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * p["norm"]["scale"]).astype(x.dtype)
    return y @ p["out_proj"]["w"].astype(x.dtype), {"ssm": ssm, "conv": conv_state}
