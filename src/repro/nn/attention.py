"""Attention blocks: GQA (grouped-query) and MLA (DeepSeek multi-head latent).

Both support three execution modes through one code path:
  * full-sequence training / prefill  (q_len == kv_len, causal)
  * incremental decode with a KV cache (q_len == 1, kv_len == cache size)

Caches are plain dicts of arrays so they shard with ordinary
``NamedSharding``s: GQA caches (k, v) of shape (B, S, H_kv, D); MLA caches
the *compressed* latent (B, S, kv_lora) + shared rope key (B, S, rope_dim),
which is the MLA memory win and is what we shard over the mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from repro import compat

from repro.models.sharding import constrain
from repro.nn.basic import lecun_normal, rmsnorm_init, rmsnorm_apply
from repro.nn.rotary import apply_rope

BIG_NEG = -2.0e38  # mask value in fp32 softmax


def _heads_divide_model(num_heads: int) -> bool:
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return False
    return num_heads % mesh.shape["model"] == 0


# ---------------------------------------------------------------------------
# core scaled-dot-product attention (XLA path; the Pallas flash kernel in
# repro/kernels mirrors this math — see kernels/ref.py)
# ---------------------------------------------------------------------------


def sdpa(q, k, v, q_positions, kv_positions, *, causal: bool = True, scale: float):
    """q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D) with H % Hkv == 0. fp32 softmax."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    q = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = q_positions[:, None, None, :, None] >= kv_positions[:, None, None, None, :]
        logits = jnp.where(mask, logits, BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h * v.shape[-1])


Q_CHUNK = 512  # query-block size for the chunked (flash-style) XLA path


def sdpa_chunked(q, k, v, q_positions, kv_positions, *, causal: bool = True,
                 scale: float, chunk: int = Q_CHUNK):
    """Query-chunked attention: O(chunk * S) score memory instead of O(S^2).

    This is the XLA analogue of the Pallas flash kernel's outer loop (the
    kernel additionally streams KV through VMEM and skips fully-masked KV
    blocks); it is what makes the 32k prefill cells fit in HBM on the
    dry-run baseline.  Each chunk body is rematerialized so the backward
    pass stores only per-chunk outputs.
    """
    b, s, h, d = q.shape
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, d), 1, 0)
    pc = jnp.moveaxis(q_positions.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(_, xs):
        qi, pi = xs
        return None, sdpa(qi, k, v, pi, kv_positions, causal=causal,
                          scale=scale)

    _, out = jax.lax.scan(body, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h * v.shape[-1])


def sdpa_auto(q, k, v, q_positions, kv_positions, *, causal: bool = True,
              scale: float):
    s = q.shape[1]
    if s > Q_CHUNK and s % Q_CHUNK == 0:
        return sdpa_chunked(q, k, v, q_positions, kv_positions, causal=causal,
                            scale=scale)
    return sdpa(q, k, v, q_positions, kv_positions, causal=causal, scale=scale)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, *, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, qkv_bias: bool = False, qk_norm: bool = False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p: dict[str, Any] = {
        "wq": {"w": lecun_normal(kq, (d_model, num_heads * head_dim))},
        "wk": {"w": lecun_normal(kk, (d_model, num_kv_heads * head_dim))},
        "wv": {"w": lecun_normal(kv, (d_model, num_kv_heads * head_dim))},
        "wo": {"w": lecun_normal(ko, (num_heads * head_dim, d_model))},
    }
    if qkv_bias:
        p["wq"]["b"] = jnp.zeros((num_heads * head_dim,), jnp.float32)
        p["wk"]["b"] = jnp.zeros((num_kv_heads * head_dim,), jnp.float32)
        p["wv"]["b"] = jnp.zeros((num_kv_heads * head_dim,), jnp.float32)
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim)
        p["k_norm"] = rmsnorm_init(head_dim)
    return p


def gqa_init_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                   dtype=jnp.bfloat16):
    shape = (batch, max_len, num_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_apply(p, x, positions, *, num_heads: int, num_kv_heads: int,
              head_dim: int, rope_theta: float = 10000.0,
              cache=None, cache_index=None, attn_fn=None):
    """x: (B,S,Dm). If ``cache`` given, S is the new-token count (decode) and
    ``cache_index`` the current fill level; returns (out, new_cache)."""
    b, s, _ = x.shape

    def proj(name, nh):
        y = x @ p[name]["w"]
        if "b" in p[name]:
            y = y + p[name]["b"].astype(y.dtype)
        return y.reshape(b, s, nh, head_dim)

    q = proj("wq", num_heads)
    k = proj("wk", num_kv_heads)
    v = proj("wv", num_kv_heads)
    if "q_norm" in p:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    q = apply_rope(q, positions, theta=rope_theta)
    k = apply_rope(k, positions, theta=rope_theta)

    if cache is None:
        # sequence-parallel -> head-parallel relayout ONCE per layer (the
        # Megatron SP pattern); keeps the chunked-attention scan free of
        # per-chunk collectives.  Only when the head count divides the model
        # axis — otherwise dropping the constraint would REPLICATE the
        # (formerly sequence-sharded) activations, a measured regression on
        # qwen2 (14/12 heads) and musicgen (24 heads).
        if _heads_divide_model(num_heads):
            q = constrain(q, "F", None, "M", None)
            k = constrain(k, "F", None, "M", None)
            v = constrain(v, "F", None, "M", None)
        else:
            q = constrain(q, "F", "M", None, None)
            k = constrain(k, "F", "M", None, None)
            v = constrain(v, "F", "M", None, None)
        kv_positions = positions
        out = (attn_fn or sdpa_auto)(q, k, v, positions, kv_positions,
                                     causal=True, scale=head_dim ** -0.5)
        out = constrain(out, "F", None, "M")
        return out @ p["wo"]["w"], None

    # decode: write new k/v at cache_index, attend over the whole cache
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1),
    }
    max_len = cache["k"].shape[1]
    kv_positions = jnp.broadcast_to(jnp.arange(max_len)[None, :], (b, max_len))
    # positions beyond the fill level are masked by causality (q position ==
    # cache_index + offset >= any unwritten slot index only if slot <= qpos).
    out = sdpa(q, new_cache["k"].astype(q.dtype), new_cache["v"].astype(q.dtype),
               positions, kv_positions, causal=True, scale=head_dim ** -0.5)
    return out @ p["wo"]["w"], new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, *, d_model: int, num_heads: int, kv_lora_rank: int,
             qk_nope_dim: int = 128, qk_rope_dim: int = 64, v_dim: int = 128):
    kq, kd, ku, ko, kr = jax.random.split(key, 5)
    return {
        "wq": {"w": lecun_normal(kq, (d_model, num_heads * (qk_nope_dim + qk_rope_dim)))},
        "w_dkv": {"w": lecun_normal(kd, (d_model, kv_lora_rank))},
        "w_kr": {"w": lecun_normal(kr, (d_model, qk_rope_dim))},
        "kv_norm": rmsnorm_init(kv_lora_rank),
        "w_ukv": {"w": lecun_normal(ku, (kv_lora_rank, num_heads * (qk_nope_dim + v_dim)))},
        "wo": {"w": lecun_normal(ko, (num_heads * v_dim, d_model))},
    }


def mla_init_cache(batch: int, max_len: int, kv_lora_rank: int,
                   qk_rope_dim: int = 64, dtype=jnp.bfloat16):
    return {"c_kv": jnp.zeros((batch, max_len, kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, qk_rope_dim), dtype)}


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, q_positions, kv_positions, *,
                num_heads, qk_nope_dim, qk_rope_dim, v_dim):
    b = q_nope.shape[0]
    skv = c_kv.shape[1]
    ukv = (c_kv @ p["w_ukv"]["w"].astype(c_kv.dtype)).reshape(
        b, skv, num_heads, qk_nope_dim + v_dim)
    k_nope, v = ukv[..., :qk_nope_dim], ukv[..., qk_nope_dim:]
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    mask = q_positions[:, None, :, None] >= kv_positions[:, None, None, :]
    logits = jnp.where(mask, logits, BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, q_nope.shape[1], num_heads * v_dim)


def mla_apply(p, x, positions, *, num_heads: int, kv_lora_rank: int,
              qk_nope_dim: int = 128, qk_rope_dim: int = 64, v_dim: int = 128,
              rope_theta: float = 10000.0, cache=None, cache_index=None):
    b, s, _ = x.shape
    q = (x @ p["wq"]["w"]).reshape(b, s, num_heads, qk_nope_dim + qk_rope_dim)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, theta=rope_theta)
    c_kv = rmsnorm_apply(p["kv_norm"], x @ p["w_dkv"]["w"])
    k_rope = apply_rope(x @ p["w_kr"]["w"], positions, theta=rope_theta)

    kw = dict(num_heads=num_heads, qk_nope_dim=qk_nope_dim,
              qk_rope_dim=qk_rope_dim, v_dim=v_dim)
    if cache is None:
        # full-sequence pass: fold MLA into standard attention with
        # head_dim = nope+rope (k_rope broadcast across heads) so the
        # chunked flash-style path applies.
        ukv = (c_kv @ p["w_ukv"]["w"].astype(x.dtype)).reshape(
            b, s, num_heads, qk_nope_dim + v_dim)
        k_nope, v = ukv[..., :qk_nope_dim], ukv[..., qk_nope_dim:]
        q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, s, num_heads, qk_rope_dim))], axis=-1)
        # (sdpa contracts the last dim of q/k and uses v's own dim, so the
        # unequal qk/v head dims of MLA are fine.)
        q_eff = constrain(q_eff, "F", None, "M", None)
        k_eff = constrain(k_eff, "F", None, "M", None)
        v = constrain(v, "F", None, "M", None)
        scale = (qk_nope_dim + qk_rope_dim) ** -0.5
        out = sdpa_auto(q_eff, k_eff, v, positions, positions, causal=True,
                        scale=scale)
        out = constrain(out, "F", None, "M")
        return out @ p["wo"]["w"], None

    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_index, axis=1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), cache_index, axis=1),
    }
    max_len = cache["c_kv"].shape[1]
    kv_positions = jnp.broadcast_to(jnp.arange(max_len)[None, :], (b, max_len))
    # ABSORBED decode (DeepSeek's matrix-absorption trick, §Perf): fold
    # w_ukv into the query and the output so attention runs directly over
    # the compressed latent — per-step cost drops from
    # O(S * kv_lora * H * (nope+v)) to O(S * kv_lora * H), ~d_head x less.
    w_ukv = p["w_ukv"]["w"].astype(x.dtype).reshape(
        -1, num_heads, qk_nope_dim + v_dim)
    w_k, w_v = w_ukv[..., :qk_nope_dim], w_ukv[..., qk_nope_dim:]
    ckv = new_cache["c_kv"].astype(x.dtype)
    kr = new_cache["k_rope"].astype(x.dtype)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_k)
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bqhl,bkl->bhqk", q_abs, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, kr,
                           preferred_element_type=jnp.float32)) * scale
    mask = positions[:, None, :, None] >= kv_positions[:, None, None, :]
    probs = jax.nn.softmax(jnp.where(mask, logits, BIG_NEG), axis=-1
                           ).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkl->bqhl", probs, ckv)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx, w_v).reshape(
        b, s, num_heads * v_dim)
    return out @ p["wo"]["w"], new_cache
