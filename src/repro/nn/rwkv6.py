"""RWKV-6 ("Finch") blocks — attention-free, data-dependent per-channel decay.

Two equivalent WKV implementations:
  * ``wkv6_scan``    — the literal recurrence (oracle; also the decode step)
  * ``wkv6_chunked`` — chunked linear-attention form (the compute-efficient
    path: intra-chunk quadratic term + inter-chunk state carry). All decay
    exponents are kept ≤ 0 (log-space cumsums) so nothing overflows.

The Pallas kernel in ``repro/kernels/wkv6.py`` mirrors the chunked form.

Recurrence per head (k-dim = v-dim = head_dim):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t = exp(-exp(ww_t)) ∈ (0,1)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain
from repro.nn.basic import lecun_normal, normal_init


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------


def wkv6_scan(r, k, v, lw, u, state):
    """Literal recurrence. r/k/v/lw: (B,S,H,D); u: (H,D); state: (B,H,D,D).

    Returns (y (B,S,H,D), final_state). lw = log(w_t) <= 0."""
    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,D)
        kv = k_t[..., :, None] * v_t[..., None, :]               # (B,H,Dk,Dv)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s = jnp.exp(lw_t)[..., :, None] * s + kv
        return s, y
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, lw))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def wkv6_chunked(r, k, v, lw, u, state, *, chunk: int = 64,
                 compute_dtype=jnp.float32):
    """Chunked parallel form, exactly equal to ``wkv6_scan`` in fp32.

    r/k/v/lw: (B,S,H,D) with S % chunk == 0; u: (H,D); state: (B,H,Dk,Dv).
    """
    b, s, h, d = r.shape
    nc, cl = s // chunk, chunk
    cd = compute_dtype
    tril = jnp.tril(jnp.ones((cl, cl), bool), k=-1)[..., None]

    # ALL chunk math lives inside the scan body (see ssd_chunked for why —
    # remat granularity must match the scan step or backward traffic blows
    # up by a factor of NC).
    @jax.checkpoint
    def body(st, inp):
        rc, kc, vc, lwc = inp                        # (B,H,CL,D)
        cl_cum = jnp.cumsum(lwc, axis=-2)
        cl_prev = cl_cum - lwc                       # sum over s<t
        cl_total = cl_cum[..., -1:, :]               # (B,H,1,D)

        r_in = rc * jnp.exp(cl_prev)                 # attends to S_0
        k_out = kc * jnp.exp(cl_total - cl_cum)      # carried to S_end

        # A[t,s] = sum_i r[t,i] k[s,i] e^{cl_prev[t,i]-cl_cum[s,i]}, s < t
        expo = cl_prev[..., :, None, :] - cl_cum[..., None, :, :]
        decay = jnp.exp(jnp.where(tril, expo, -jnp.inf)).astype(cd)
        a = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc.astype(cd), kc.astype(cd),
                       decay, preferred_element_type=jnp.float32)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rc, u, kc)
        a = a + jnp.eye(cl, dtype=a.dtype) * diag[..., :, None]

        y = jnp.einsum("bhtd,bhdv->bhtv", r_in, st) + jnp.einsum(
            "bhts,bhsv->bhtv", a, vc)
        st = jnp.exp(cl_total.squeeze(-2))[..., :, None] * st + jnp.einsum(
            "bhsd,bhsv->bhdv", k_out, vc)
        return st, y

    def to_chunks(x):  # (B,S,H,D) -> (NC,B,H,CL,D)
        return jnp.moveaxis(jnp.moveaxis(x.reshape(b, nc, cl, h, d), 3, 2), 1, 0)

    xs = tuple(map(to_chunks, (r, k, v, lw)))
    final, ys = jax.lax.scan(body, state, xs)
    ys = jnp.moveaxis(ys, 0, 1)                      # (B,NC,H,CL,D)
    return jnp.moveaxis(ys, 2, 3).reshape(b, s, h, d), final


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------


def rwkv6_block_init(key, *, d_model: int, d_ff: int, head_dim: int = 64,
                     mix_lora: int = 32, decay_lora: int = 64):
    ks = jax.random.split(key, 12)
    h = d_model // head_dim
    tm = {
        "mix_base": 0.5 * jnp.ones((5, d_model), jnp.float32),   # r,k,v,w,g
        "mix_w1": normal_init(ks[0], (d_model, 5 * mix_lora), std=0.01),
        "mix_w2": normal_init(ks[1], (5, mix_lora, d_model), std=0.01),
        "decay_base": jnp.zeros((d_model,), jnp.float32) - 4.0,
        "decay_w1": normal_init(ks[2], (d_model, decay_lora), std=0.01),
        "decay_w2": normal_init(ks[3], (decay_lora, d_model), std=0.01),
        "bonus": normal_init(ks[4], (h, head_dim), std=0.3),
        "wr": {"w": lecun_normal(ks[5], (d_model, d_model))},
        "wk": {"w": lecun_normal(ks[6], (d_model, d_model))},
        "wv": {"w": lecun_normal(ks[7], (d_model, d_model))},
        "wg": {"w": lecun_normal(ks[8], (d_model, d_model))},
        "wo": {"w": lecun_normal(ks[9], (d_model, d_model))},
        "ln_x": {"scale": jnp.ones((d_model,), jnp.float32),
                 "bias": jnp.zeros((d_model,), jnp.float32)},
    }
    cm = {
        "mix_k": 0.5 * jnp.ones((d_model,), jnp.float32),
        "mix_r": 0.5 * jnp.ones((d_model,), jnp.float32),
        "wk": {"w": lecun_normal(ks[10], (d_model, d_ff))},
        "wv": {"w": lecun_normal(ks[11], (d_ff, d_model))},
        "wr": {"w": lecun_normal(jax.random.fold_in(key, 99), (d_model, d_model))},
    }
    return {"time_mix": tm, "channel_mix": cm}


def rwkv6_init_state(batch: int, d_model: int, head_dim: int = 64,
                     dtype=jnp.float32):
    h = d_model // head_dim
    return {
        "wkv": jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        "tm_x": jnp.zeros((batch, d_model), dtype),
        "cm_x": jnp.zeros((batch, d_model), dtype),
    }


def _group_norm(p, x, n_heads: int, eps: float = 64e-5):
    """Per-head layer norm over head channels. x: (B,S,D)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(b, s, d)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def time_mix_apply(p, x, x_prev, wkv_state, *, head_dim: int = 64,
                   use_chunked: bool = True, chunk: int = 64,
                   compute_dtype=jnp.float32, use_kernels=None):
    """x: (B,S,D); x_prev: (B,1,D) token before x[:,0]. Returns y, new state."""
    b, s, d = x.shape
    h = d // head_dim
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    dx = xs - x
    xxx = x + dx * p["mix_base"].astype(x.dtype).mean(0)
    lora = jnp.tanh(xxx @ p["mix_w1"].astype(x.dtype))
    lora = lora.reshape(b, s, 5, -1)
    deltas = jnp.einsum("bsli,lid->bsld", lora, p["mix_w2"].astype(x.dtype))
    mixed = x[:, :, None] + dx[:, :, None] * (p["mix_base"].astype(x.dtype) + deltas)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = (xr @ p["wr"]["w"].astype(x.dtype)).reshape(b, s, h, head_dim)
    k = (xk @ p["wk"]["w"].astype(x.dtype)).reshape(b, s, h, head_dim)
    v = (xv @ p["wv"]["w"].astype(x.dtype)).reshape(b, s, h, head_dim)
    g = jax.nn.silu(xg @ p["wg"]["w"].astype(x.dtype))

    ww = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_w1"].astype(x.dtype)) @ p["decay_w2"].astype(x.dtype)
    ).astype(jnp.float32)
    lw = -jnp.exp(ww).reshape(b, s, h, head_dim)                 # log decay <= 0
    u = p["bonus"].astype(jnp.float32)

    # sequence-parallel -> head-parallel relayout (see mamba2_block_apply)
    r = constrain(r, "F", None, "M", None)
    k = constrain(k, "F", None, "M", None)
    v = constrain(v, "F", None, "M", None)
    lw = constrain(lw, "F", None, "M", None)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    from repro.kernels.ops import wkv6_apply  # lazy: ops falls back to us
    y, new_state = wkv6_apply(r32, k32, v32, lw, u, wkv_state,
                              use_chunked=use_chunked, chunk=chunk,
                              compute_dtype=compute_dtype,
                              use_kernels=use_kernels)
    y = constrain(y, "F", None, "M", None)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = _group_norm(p["ln_x"], y, h) * g
    return y @ p["wo"]["w"].astype(x.dtype), new_state, x[:, -1:]


def channel_mix_apply(p, x, x_prev):
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    dx = xs - x
    xk = x + dx * p["mix_k"].astype(x.dtype)
    xr = x + dx * p["mix_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]["w"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["wr"]["w"].astype(x.dtype))
    return r * (k @ p["wv"]["w"].astype(x.dtype)), x[:, -1:]
