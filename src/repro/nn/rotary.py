"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (d/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, d/2)
    if x.ndim == angles.ndim + 1:                            # head axis present
        angles = angles[..., None, :]                        # (..., S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
