"""Mixture-of-Experts layer (GShard-style capacity-based einsum dispatch).

Design notes (TPU adaptation):
  * dispatch/combine are expressed as einsums over a (groups, tokens, experts,
    capacity) one-hot tensor — this is the canonical XLA-shardable MoE
    formulation: with the expert axis sharded over the ``model`` mesh axis and
    token groups sharded over ``data``, XLA lowers the dispatch einsum to an
    all-to-all (visible in the dry-run HLO, counted by the roofline pass).
  * FLOPs stay proportional to *activated* tokens (T·top_k·capacity_factor),
    not to the number of experts, so `cost_analysis()` reflects the 6·N_active
    model-FLOPs accounting used in EXPERIMENTS.md.
  * tokens over capacity are dropped (residual passthrough), standard for
    capacity-based routing.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.basic import lecun_normal, glu_mlp_init, glu_mlp_apply


def moe_init(key, *, d_model: int, d_expert: int, num_experts: int,
             num_shared: int = 0):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p: dict[str, Any] = {
        "router": {"w": lecun_normal(kr, (d_model, num_experts))},
        "experts": {
            "w_gate": lecun_normal(kg, (num_experts, d_model, d_expert), in_axis=-2),
            "w_up": lecun_normal(ku, (num_experts, d_model, d_expert), in_axis=-2),
            "w_down": lecun_normal(kd, (num_experts, d_expert, d_model), in_axis=-2),
        },
    }
    if num_shared:
        p["shared"] = glu_mlp_init(ks, d_model, d_expert * num_shared)
    return p


def _top_k_gating(router_logits, top_k: int, *, normalize: bool = True):
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)           # (..., k)
    if normalize:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return probs, gates, idx


def _dispatch_combine(gates, idx, num_experts: int, capacity: int):
    """gates/idx: (B, G, T, k). Returns combine (B,G,T,E,C) and dispatch.

    The two leading group dims (batch, seq-groups) are kept EXPLICIT so the
    mesh sharding of tokens (batch over 'data', seq over 'model') propagates
    into every dispatch einsum — flattening them forced XLA to all-reduce the
    full combine tensor per layer (§Perf iteration 2)."""
    b, g, t, k = idx.shape
    onehot = jax.nn.one_hot(idx, num_experts, dtype=jnp.float32)  # (B,G,T,k,E)
    # position of each (token, slot) in its expert's queue, counting slot-major
    # then token-major (GShard ordering); (t, k) are group-local dims.
    flat = onehot.swapaxes(2, 3).reshape(b, g, k * t, num_experts)
    pos_flat = jnp.cumsum(flat, axis=2) - flat                    # (B,G,k*T,E)
    pos = pos_flat.reshape(b, g, k, t, num_experts).swapaxes(2, 3)
    pos = jnp.sum(pos * onehot, axis=-1)                          # (B,G,T,k)
    keep = (pos < capacity).astype(jnp.float32)
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                dtype=jnp.float32)
    combine = jnp.einsum("bgtk,bgtke,bgtkc->bgtec", gates * keep, onehot,
                         cap_onehot)
    dispatch = (combine > 0).astype(jnp.bfloat16)
    return combine, dispatch


def load_balancing_loss(probs, idx, num_experts: int):
    """Switch/GShard aux loss: E * sum_e mean(prob_e) * mean(frac routed to e)."""
    counts = jnp.sum(jax.nn.one_hot(idx, num_experts, dtype=jnp.float32), axis=(-3, -2))
    frac = counts / jnp.maximum(jnp.sum(counts, axis=-1, keepdims=True), 1.0)
    mean_prob = jnp.mean(probs, axis=-2)
    return num_experts * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))


def moe_apply(p, x, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, group_size: int = 256,
              activation: str = "silu"):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    Groups are formed by splitting the SEQUENCE axis only ((B, S, D) ->
    (B, S/gs, gs, D)); batch and seq-group dims stay explicit so token
    sharding survives the dispatch (see _dispatch_combine)."""
    b, s, d = x.shape
    gs = min(group_size, s)
    while s % gs:                  # keep groups exact for any seq length
        gs -= 1
    g = s // gs
    xg = x.reshape(b, g, gs, d)

    probs, gates, idx = _top_k_gating(
        jnp.einsum("bgtd,de->bgte", xg, p["router"]["w"].astype(x.dtype)),
        top_k)
    capacity = max(top_k, int(math.ceil(gs * top_k * capacity_factor / num_experts)))
    combine, dispatch = _dispatch_combine(gates, idx, num_experts, capacity)

    we = p["experts"]
    xs = jnp.einsum("bgtec,bgtd->bgecd", dispatch, xg)         # (B,G,E,C,D)
    hg = jax.nn.silu(jnp.einsum("bgecd,edf->bgecf", xs,
                                we["w_gate"].astype(x.dtype)))
    hu = jnp.einsum("bgecd,edf->bgecf", xs, we["w_up"].astype(x.dtype))
    ye = jnp.einsum("bgecf,efd->bgecd", hg * hu, we["w_down"].astype(x.dtype))
    out = jnp.einsum("bgtec,bgecd->bgtd", combine.astype(x.dtype), ye)
    out = out.reshape(b, s, d)

    if "shared" in p:
        out = out + glu_mlp_apply(p["shared"], x, activation=activation)
    aux = load_balancing_loss(probs, idx, num_experts)
    return out, aux
