"""Basic functional neural-network building blocks.

Everything is pure-functional: ``init_*`` returns a pytree of parameters
(plain nested dicts of ``jnp.ndarray``), ``*_apply`` consumes it.  No
framework dependency — this substitutes flax/haiku which are unavailable.

Parameters are stored in float32 ("master" precision); compute casts to the
model dtype at apply time (mixed-precision recipe).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def lecun_normal(key, shape, in_axis: int = -2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def kaiming_uniform(key, shape, dtype=jnp.float32):
    """Matches torch.nn.init.kaiming_uniform_(a=sqrt(5)) used by the paper's
    VectorizedLinearLayer snippet (Appendix C)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    gain = math.sqrt(2.0 / (1.0 + 5.0))
    bound = gain * math.sqrt(3.0 / fan_in)
    return jax.random.uniform(key, shape, dtype, -bound, bound)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def cast(tree, dtype):
    """Cast all floating leaves of a pytree to ``dtype`` (compute precision)."""
    def _c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_c, tree)


# ---------------------------------------------------------------------------
# linear / mlp
# ---------------------------------------------------------------------------


def linear_init(key, in_features: int, out_features: int, *, bias: bool = True,
                init=lecun_normal):
    kw, kb = jax.random.split(key)
    p = {"w": init(kw, (in_features, out_features))}
    if bias:
        p["b"] = jnp.zeros((out_features,), jnp.float32)
    return p


def linear_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


_ACTS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def mlp_init(key, sizes: Sequence[int], *, bias: bool = True):
    """Plain MLP (the paper's SAC/TD3 torso): sizes = [in, h1, ..., out]."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {f"layer_{i}": linear_init(k, sizes[i], sizes[i + 1], bias=bias)
            for i, k in enumerate(keys)}


def mlp_apply(p, x, *, activation: str = "relu", final_activation: str | None = None):
    n = len(p)
    act = _ACTS[activation]
    for i in range(n):
        x = linear_apply(p[f"layer_{i}"], x)
        if i < n - 1:
            x = act(x)
        elif final_activation is not None:
            x = _ACTS[final_activation](x)
    return x


def glu_mlp_init(key, d_model: int, d_ff: int, *, bias: bool = False):
    """Gated MLP (SwiGLU/GeGLU): gate/up/down projections."""
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": {"w": lecun_normal(kg, (d_model, d_ff))},
        "w_up": {"w": lecun_normal(ku, (d_model, d_ff))},
        "w_down": {"w": lecun_normal(kd, (d_ff, d_model))},
    }


def glu_mlp_apply(p, x, *, activation: str = "silu"):
    act = _ACTS[activation]
    g = act(x @ p["w_gate"]["w"])
    u = x @ p["w_up"]["w"]
    return (g * u) @ p["w_down"]["w"]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"] + p["bias"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, dim: int, std: float = 0.02):
    return {"embedding": normal_init(key, (vocab, dim), std=std)}


def embedding_apply(p, ids, dtype=None):
    emb = p["embedding"]
    if dtype is not None:
        emb = emb.astype(dtype)
    return jnp.take(emb, ids, axis=0)


# ---------------------------------------------------------------------------
# conv stack (DQN Atari-style torso)
# ---------------------------------------------------------------------------


def conv_init(key, in_ch: int, out_ch: int, kernel: int):
    kw, kb = jax.random.split(key)
    fan_in = in_ch * kernel * kernel
    std = 1.0 / math.sqrt(fan_in)
    return {
        "w": std * jax.random.truncated_normal(kw, -2., 2., (kernel, kernel, in_ch, out_ch)),
        "b": jnp.zeros((out_ch,), jnp.float32),
    }


def conv_apply(p, x, stride: int):
    # x: (B, H, W, C)
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def dqn_torso_init(key, in_ch: int = 4):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv_0": conv_init(k1, in_ch, 32, 8),
        "conv_1": conv_init(k2, 32, 64, 4),
        "conv_2": conv_init(k3, 64, 64, 3),
    }


def dqn_torso_apply(p, x):
    x = jax.nn.relu(conv_apply(p["conv_0"], x, 4))
    x = jax.nn.relu(conv_apply(p["conv_1"], x, 2))
    x = jax.nn.relu(conv_apply(p["conv_2"], x, 1))
    return x.reshape(x.shape[:-3] + (-1,))
