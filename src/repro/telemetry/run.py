"""``RunTelemetry`` — one object that turns a training/serving run into a
structured, reconstructable record.

Owned by ``PopTrainer`` (and shared with the rollout engine, the serving
stack and the launchers); everything it records flows through one
:class:`~repro.telemetry.sink.MetricsSink`, so a run log is a single JSONL
stream ``tools/report.py`` can replay into a PBT family tree, per-member
hyper trajectories, per-phase timing and compile-event counts.

Design constraint (the one that makes this engineering, not logging glue):
**nothing here may touch array values on the caller's thread.**  Phase
timers are host wall-clock (``perf_counter``) around *dispatch*; rows
carry jax arrays by reference and the sink's writer thread fetches them
after they have materialized.  The fused train iteration and the ensemble
serve call stay ONE jitted donated call each — asserted by the
transfer-guard tests running with a live JSONL sink attached.

Compile tracking rides ``repro.compat.register_compile_listener`` (jax's
monitoring events): every XLA backend compile becomes a ``compile`` row
stamped with the current attribution label — ``"warmup"`` until the first
iteration completes, ``"steady"`` after, or whatever an enclosing
:meth:`compile_scope` says (``launch/train.py`` wraps elastic resume in
``compile_scope("resize")``, which is exactly the compile-dominated resize
tail PR 3/PR 5 measured).
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager

import jax

from repro import compat
from repro.telemetry.sink import MetricsSink, NullSink


def _run_id() -> str:
    return f"{int(time.time()):x}-{os.getpid():x}"


def make_telemetry(log_dir=None, *, console: bool = True,
                   console_every: int = 10, meta=None) -> "RunTelemetry":
    """The launcher/example recipe: JSONL into ``log_dir/telemetry.jsonl``
    when a log dir is given, plus the console sink (iter rows throttled to
    one in ``console_every``) — the ONE formatting path that replaced the
    per-example print zoo."""
    from repro.telemetry.sink import ConsoleSink, JSONLSink, MultiSink

    sinks = []
    if log_dir:
        from pathlib import Path
        sinks.append(JSONLSink(Path(log_dir) / "telemetry.jsonl"))
    if console:
        sinks.append(ConsoleSink(every=console_every))
    if not sinks:
        return RunTelemetry(None, meta=meta)
    sink = sinks[0] if len(sinks) == 1 else MultiSink(sinks)
    return RunTelemetry(sink, meta=meta)


class RunTelemetry:
    """Phase timers + structured rows over one sink.

    ``sink=None`` builds a disabled instance (``enabled`` False): every
    method stays callable and cheap, so instrumented code never branches
    on "is telemetry on".  ``meta`` lands in the run-header row (config,
    argv, whatever identifies the run); ``track_compiles`` registers the
    compat compile listener for this object's lifetime.
    """

    def __init__(self, sink: MetricsSink | None = None, *, meta=None,
                 run_id: str | None = None, track_compiles: bool = True):
        self.enabled = sink is not None
        self.sink = sink if sink is not None else NullSink()
        self.run_id = run_id or _run_id()
        self._t0 = time.perf_counter()
        self._phases: dict[str, float] = {}
        self._blocks: dict[str, float] = {}
        self._compile_label = "warmup"
        self.compile_count = 0
        self.compile_secs = 0.0
        self._unregister = None
        self._profiling = False
        if self.enabled:
            self.sink.write({
                "kind": "run", "run_id": self.run_id,
                "jax": jax.__version__,
                "devices": len(jax.devices()),
                "platform": jax.devices()[0].platform,
                "meta": dict(meta or {})})
            if track_compiles:
                self._unregister = compat.register_compile_listener(
                    self._on_compile)

    # -------------------------------------------------------------- timing
    def _stamp(self) -> float:
        return round(time.perf_counter() - self._t0, 6)

    @contextmanager
    def phase(self, name: str):
        """Accumulate host wall-clock of the enclosed block into ``name``
        for the current iteration row.  Times *dispatch*, deliberately: a
        fused call's device time shows up as whichever later phase blocks
        on its results (or in the profiler trace — this is a cheap
        always-on timer, not a tracer)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._phases[name] = self._phases.get(name, 0.0) + dt

    def block(self, name: str, value):
        """The other half of the dispatch/block split: wait for ``value``'s
        arrays to materialize (``jax.block_until_ready``) and accumulate
        the wait into the iteration row's ``blocks`` dict.  ``phases``
        measure what the host *spends* enqueueing work; ``blocks`` measure
        what it *waits* for — a serial engine's block covers the whole
        iteration (block ≈ wall), an overlapped engine's only the update,
        because acting for the next iteration is already enqueued behind it
        and never waited on.  Blocking is a measurement choice: call sites
        opt in (``run_env_loop(block_every=...)``, benchmark drivers), the
        hot path never blocks.  Returns ``value``."""
        t0 = time.perf_counter()
        jax.block_until_ready(value)
        dt = time.perf_counter() - t0
        self._blocks[name] = self._blocks.get(name, 0.0) + dt
        return value

    # --------------------------------------------------------------- rows
    def record(self, kind: str, **fields):
        """Emit one generic row (stamped with ``t``).  The escape hatch for
        example-specific diagnostics — same pipe, same formats."""
        self.sink.write(dict(fields, kind=kind, t=self._stamp()))

    def record_iteration(self, step: int, *, metrics=None, stats=None,
                         did_update=None, **extra):
        """Close out one train iteration: the accumulated phase timers plus
        whatever the iteration produced.  ``metrics``/``stats`` may be jax
        arrays — passed by reference, fetched on the sink thread."""
        phases = {k: round(v, 6) for k, v in self._phases.items()}
        self._phases.clear()
        if self._compile_label == "warmup":
            self._compile_label = "steady"
        row = {"kind": "iter", "t": self._stamp(), "step": step,
               "phases": phases, **extra}
        if self._blocks:
            row["blocks"] = {k: round(v, 6)
                             for k, v in self._blocks.items()}
            self._blocks.clear()
        if metrics is not None:
            row["metrics"] = metrics
        if stats is not None:
            row["stats"] = stats
        if did_update is not None:
            # may be a device scalar: no bool() here — the sink thread
            # converts, keeping this call sync-free on the train loop
            row["did_update"] = did_update
        self.sink.write(row)

    def record_members(self, step: int, *, fitness=None, hypers=None):
        """Per-member population-health snapshot: fitness and the dynamic
        hyperparameters.  The time series of these rows IS the hyper
        trajectory ``tools/report.py`` reconstructs."""
        row = {"kind": "members", "t": self._stamp(), "step": step}
        if fitness is not None:
            row["fitness"] = fitness
        if hypers is not None:
            row["hypers"] = hypers
        self.sink.write(row)

    def record_evolve(self, step: int, parents, *, fitness=None,
                      strategy=None):
        """One lineage event: ``parents[i]`` is the member whose state
        member ``i`` now holds (-1 = drawn fresh from a distribution)."""
        row = {"kind": "evolve", "t": self._stamp(), "step": step,
               "parents": parents}
        if fitness is not None:
            row["fitness"] = fitness
        if strategy is not None:
            row["strategy"] = strategy
        self.sink.write(row)

    def record_ckpt(self, step: int, secs: float, **extra):
        self.sink.write({"kind": "ckpt", "t": self._stamp(), "step": step,
                         "secs": round(secs, 6), **extra})

    # ------------------------------------------------------------ compiles
    def _on_compile(self, event: str, secs: float):
        self.compile_count += 1
        self.compile_secs += secs
        self.sink.write({"kind": "compile", "t": self._stamp(),
                         "event": event.rsplit("/", 1)[-1],
                         "secs": round(secs, 6),
                         "label": self._compile_label,
                         "count": self.compile_count})

    @contextmanager
    def compile_scope(self, label: str):
        """Attribute compilations inside the block to ``label`` (e.g.
        ``"resize"`` around an elastic re-layout, ``"promotion"`` around a
        serving-set swap of a new ensemble size)."""
        prev, self._compile_label = self._compile_label, label
        try:
            yield
        finally:
            self._compile_label = prev

    # ------------------------------------------------------------ profiler
    def start_profile(self, trace_dir):
        """Begin a ``jax.profiler`` device trace into ``trace_dir``."""
        if self._profiling:
            return
        jax.profiler.start_trace(str(trace_dir))
        self._profiling = True
        self.record("profile", action="start", dir=str(trace_dir))

    def stop_profile(self):
        if not self._profiling:
            return
        jax.profiler.stop_trace()
        self._profiling = False
        self.record("profile", action="stop")

    def tick_profile(self, it: int, trace_dir, *, start: int = 1,
                     iters: int = 3):
        """Bounded profiling window for a driver loop: start the trace at
        iteration ``start`` (default 1 — after the warmup compile, so the
        trace shows steady state) and stop it ``iters`` iterations later.
        Call once per iteration; no-op when ``trace_dir`` is falsy."""
        if not trace_dir:
            return
        if it == start:
            self.start_profile(trace_dir)
        elif it == start + iters:
            self.stop_profile()

    # ------------------------------------------------------------ lifetime
    def close(self):
        """Stop the compile listener, stop any open trace, and close the
        sink (draining the writer thread)."""
        self.stop_profile()
        if self._unregister is not None:
            self._unregister()
            self._unregister = None
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
