"""Metrics sinks: where telemetry rows go, without blocking training.

A *row* is a flat-ish dict; the only keys every row must carry are

  * ``kind`` — the row type (``"iter"``, ``"evolve"``, ``"serve"``, ...;
    see :data:`ROW_KINDS` for the per-kind required fields), and
  * ``t`` — seconds since the sink was opened (stamped by the sink when
    the producer didn't).

Everything else is kind-specific.  Values may be jax/numpy arrays: every
sink hands rows to a **background writer thread** which is where the
device->host fetch (``np.asarray``) happens — by the time the worker gets
to a row its arrays are long materialized (the fused call that produced
them was dispatched an iteration ago), so the train loop never blocks on
telemetry IO *or* on pulling metric bytes off the device.  Crucially the
worker thread is outside any ``jax.transfer_guard`` context the main
thread holds (the guard is thread-local), which is what lets the
transfer-guard tests assert the hot path moves no bytes *while a live
JSONL sink is attached*.

Sinks:

  * :class:`JSONLSink`  — one JSON object per line; the canonical format
    (``tools/report.py`` consumes it, benchmarks emit it).
  * :class:`CSVSink`    — one row kind per file, header from the first row.
  * :class:`ConsoleSink`— the single human-formatting path (replaces the
    per-example ``print`` zoo).
  * :class:`MultiSink`  — fan-out to several sinks.
  * :class:`NullSink`   — the disabled case; ``write`` is a no-op.
"""
from __future__ import annotations

import json
import queue
import threading
import time
from pathlib import Path

import numpy as np

# Required fields per row kind (beyond "kind" and "t").  ``tools/report.py
# --check`` and the sink-side validation both read this table; a kind not
# listed here is legal (user-defined rows) but only checked for kind/t.
ROW_KINDS: dict[str, tuple] = {
    "run": ("run_id",),                      # header: config, devices, ...
    "iter": ("step", "phases"),              # per-iteration timings:
    #   "phases" — host DISPATCH wall-clock per phase (time spent
    #     enqueueing device work; never includes waiting on results);
    #   "blocks" (optional) — host WAIT wall-clock per name
    #     (``RunTelemetry.block``: timed ``jax.block_until_ready``).
    #   Serial engine: block ≈ device wall per iteration.  Overlapped
    #   engine (policy_lag=1): block covers only the update — collect
    #   dispatch hides under it, which is the overlap win report.py shows.
    "members": ("step",),                    # per-member fitness/hypers
    "evolve": ("step", "parents"),           # lineage event
    "compile": ("event", "secs", "label"),   # one XLA compilation
    "ckpt": ("step", "secs"),                # checkpoint save
    "serve": ("count", "p50_ms", "p99_ms"),  # serving latency window
    "promotion": ("step", "members"),        # serving-set audit event
    "engine": ("algo",),                     # rollout engine config
    "profile": ("action",),                  # profiler start/stop marker
    "bench": ("bench",),                     # benchmark result row
}


def validate_row(row) -> str | None:
    """None when ``row`` is schema-valid, else a human-readable error."""
    if not isinstance(row, dict):
        return f"row is {type(row).__name__}, not a dict"
    kind = row.get("kind")
    if not isinstance(kind, str):
        return f"row lacks a string 'kind': {row!r}"
    if not isinstance(row.get("t"), (int, float)):
        return f"{kind} row lacks a numeric 't'"
    missing = [f for f in ROW_KINDS.get(kind, ()) if f not in row]
    if missing:
        return f"{kind} row lacks required fields {missing}"
    return None


def jsonable(value):
    """Recursively convert a row value to plain JSON types.  Runs on the
    sink's writer thread — this is the device->host fetch point for jax
    arrays, deliberately off the train loop's thread."""
    if isinstance(value, float):
        # json can't carry NaN/Inf portably; stringify the rare ones
        return value if np.isfinite(value) else str(value)
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, dict):
        return {str(k): jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [jsonable(v) for v in value]
    arr = np.asarray(value)
    if arr.ndim == 0:
        item = arr.item()
        if isinstance(item, float) and not np.isfinite(item):
            return str(item)
        return item
    return jsonable(arr.tolist())


class MetricsSink:
    """Protocol: ``write(row)`` must be non-blocking; ``flush()`` waits for
    everything written so far to hit the backing store; ``close()`` flushes
    and releases resources.  Sinks are also context managers."""

    def write(self, row: dict):
        raise NotImplementedError

    def flush(self):
        pass

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class NullSink(MetricsSink):
    def write(self, row: dict):
        pass


class _ThreadedSink(MetricsSink):
    """Queue + daemon writer thread shared by the concrete sinks.

    ``write`` enqueues the raw row (arrays included) and returns; the
    worker converts with :func:`jsonable` and calls :meth:`_emit`.  A row
    that fails to convert or validate is reported once and dropped —
    telemetry must never take the run down."""

    _CLOSE = object()

    def __init__(self, *, strict: bool = False):
        self._t0 = time.perf_counter()
        self._q: queue.Queue = queue.Queue()
        self._strict = strict
        self._errors: list[str] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ producer
    def write(self, row: dict):
        if "t" not in row:
            row = dict(row, t=round(time.perf_counter() - self._t0, 6))
        self._q.put(row)

    def flush(self):
        done = threading.Event()
        self._q.put(done)
        done.wait(timeout=30)

    def close(self):
        if self._thread is None:
            return
        self._q.put(self._CLOSE)
        self._thread.join(timeout=30)
        self._thread = None
        self._close_backend()
        if self._strict and self._errors:
            raise ValueError("telemetry sink saw invalid rows:\n"
                             + "\n".join(self._errors))

    # -------------------------------------------------------------- worker
    def _worker(self):
        while True:
            item = self._q.get()
            if item is self._CLOSE:
                self._flush_backend()
                return
            if isinstance(item, threading.Event):
                self._flush_backend()
                item.set()
                continue
            try:
                row = jsonable(item)
                err = validate_row(row)
                if err is not None:
                    self._errors.append(err)
                    if not self._strict:
                        continue
                else:
                    self._emit(row)
            except Exception as e:  # pragma: no cover - defensive
                self._errors.append(f"{type(e).__name__}: {e}")

    def _emit(self, row: dict):
        raise NotImplementedError

    def _flush_backend(self):
        pass

    def _close_backend(self):
        pass


class JSONLSink(_ThreadedSink):
    """The canonical sink: one JSON object per line, append-only.

    ``path``'s parent directories are created.  The same format is what
    ``benchmarks/common.write_rows`` produces and ``tools/report.py``
    consumes, so CI benchmark artifacts and run logs are one schema."""

    def __init__(self, path, *, strict: bool = False):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._file = open(self.path, "a", buffering=1)
        super().__init__(strict=strict)

    def _emit(self, row: dict):
        self._file.write(json.dumps(row, separators=(",", ":")) + "\n")

    def _flush_backend(self):
        self._file.flush()

    def _close_backend(self):
        self._file.close()


class CSVSink(_ThreadedSink):
    """CSV for spreadsheet people.  Row kinds have different fields, so the
    sink keeps ONE file per kind (``path`` stem + ``.<kind>.csv``), header
    taken from the first row of that kind; later rows are projected onto
    that header (missing -> empty, extra -> dropped).  Nested values are
    JSON-encoded in their cell."""

    def __init__(self, path, *, kinds: tuple | None = None):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._kinds = kinds
        self._files: dict[str, tuple] = {}   # kind -> (file, fields)
        super().__init__()

    def _emit(self, row: dict):
        kind = row["kind"]
        if self._kinds is not None and kind not in self._kinds:
            return
        if kind not in self._files:
            f = open(self.path.with_suffix(f".{kind}.csv"), "w", buffering=1)
            fields = list(row)
            f.write(",".join(fields) + "\n")
            self._files[kind] = (f, fields)
        f, fields = self._files[kind]
        cells = []
        for name in fields:
            v = row.get(name, "")
            if isinstance(v, (dict, list)):
                v = json.dumps(v, separators=(",", ":")).replace(",", ";")
            cells.append(str(v))
        f.write(",".join(cells) + "\n")

    def _flush_backend(self):
        for f, _ in self._files.values():
            f.flush()

    def _close_backend(self):
        for f, _ in self._files.values():
            f.close()


class ConsoleSink(_ThreadedSink):
    """THE human formatting path — every example and launcher prints
    through this one sink instead of rolling its own f-strings.

    ``every`` throttles the high-rate ``iter``/``members`` rows (print one
    in N); event rows (evolve, promotion, ckpt, serve, ...) always print.
    ``compile`` rows never print — a CPU run emits hundreds and they
    belong in the JSONL record (``tools/report.py`` summarizes them; the
    run_end row carries the count).  Unknown kinds print generically, so
    example-specific diagnostics ride the same pipe."""

    THROTTLED = ("iter", "members")
    QUIET = ("compile",)

    def __init__(self, *, every: int = 1, prefix: str = ""):
        self.every = max(1, every)
        self.prefix = prefix
        self._seen: dict[str, int] = {}
        super().__init__()

    @staticmethod
    def _fmt_val(v):
        if isinstance(v, float):
            return f"{v:+.3f}" if abs(v) < 1e4 else f"{v:.3e}"
        if isinstance(v, list):
            flat = [x for x in v if isinstance(x, (int, float))]
            if flat and len(flat) == len(v):
                return (f"mean{sum(flat) / len(flat):+.3f}/"
                        f"max{max(flat):+.3f}")
            return json.dumps(v)
        if isinstance(v, dict):
            return "{" + " ".join(
                f"{k}={ConsoleSink._fmt_val(x)}" for k, x in v.items()) + "}"
        return str(v)

    def _emit(self, row: dict):
        kind = row["kind"]
        if kind in self.QUIET:
            return
        if kind in self.THROTTLED:
            n = self._seen[kind] = self._seen.get(kind, 0) + 1
            if (n - 1) % self.every:
                return
        head = f"{self.prefix}[{kind}"
        if "step" in row:
            head += f" {row['step']}"
        head += "]"
        body = " ".join(
            # a lineage's parents are identities, not a distribution —
            # print the list itself, not mean/max
            f"{k}={json.dumps(v) if k == 'parents' else self._fmt_val(v)}"
            for k, v in row.items()
            if k not in ("kind", "step", "t", "run_id"))
        print(f"{head} {body} ({row['t']:.1f}s)", flush=True)


class MultiSink(MetricsSink):
    """Fan one row stream out to several sinks (e.g. JSONL for the record,
    Console for the operator)."""

    def __init__(self, sinks):
        self.sinks = list(sinks)

    def write(self, row: dict):
        for s in self.sinks:
            s.write(row)

    def flush(self):
        for s in self.sinks:
            s.flush()

    def close(self):
        for s in self.sinks:
            s.close()
