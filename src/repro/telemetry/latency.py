"""``LatencyWindow`` — the serving-side histogram behind ``serve`` rows.

Accumulates per-request-batch latencies (plus batch-fill/padding ratio and
queue depth) on the host and summarizes into one row per window, so a
server answering thousands of requests emits dozens of rows, not
thousands.  Pure numpy, no device traffic — safe to drive from the
``BatchServer`` host path without touching its single jitted call.
"""
from __future__ import annotations

import numpy as np


class LatencyWindow:
    """Rolling window of request latencies + batching health."""

    def __init__(self):
        self.reset()

    def reset(self):
        self._lat: list[float] = []
        self._fill: list[float] = []
        self._queue_depth_max = 0
        self._requests = 0

    @property
    def count(self) -> int:
        return len(self._lat)

    def add(self, seconds: float, *, fill: float | None = None,
            requests: int = 1):
        """One served batch: wall latency, the fraction of padded slots
        that carried real requests, and how many requests it answered."""
        self._lat.append(seconds)
        if fill is not None:
            self._fill.append(fill)
        self._requests += requests

    def observe_queue(self, depth: int):
        self._queue_depth_max = max(self._queue_depth_max, depth)

    def summary(self) -> dict:
        """The ``serve`` row body: p50/p99/mean latency (ms), request and
        batch counts, mean fill ratio, max queue depth."""
        lat = np.asarray(self._lat, np.float64)
        out = {"count": int(lat.size), "requests": int(self._requests)}
        if lat.size:
            out.update(
                p50_ms=round(1e3 * float(np.percentile(lat, 50)), 3),
                p99_ms=round(1e3 * float(np.percentile(lat, 99)), 3),
                mean_ms=round(1e3 * float(lat.mean()), 3))
        else:
            out.update(p50_ms=None, p99_ms=None, mean_ms=None)
        if self._fill:
            out["fill"] = round(float(np.mean(self._fill)), 4)
        if self._queue_depth_max:
            out["queue_depth_max"] = int(self._queue_depth_max)
        return out
