"""``repro.telemetry`` — structured run telemetry behind one non-blocking
sink.

The package turns every training/serving run into evidence: phase timers,
per-member population health, lineage events, XLA compile tracking and
serving latency all flow as schema'd rows through a background-thread
sink (JSONL canonical; CSV/console/fan-out variants), without ever
touching array values on the train loop's thread — the fused iteration
and the ensemble serve call stay ONE jitted donated call each.

    from repro.telemetry import RunTelemetry, JSONLSink, ConsoleSink, MultiSink
    tel = RunTelemetry(MultiSink([JSONLSink(log_dir / "telemetry.jsonl"),
                                  ConsoleSink(every=10)]),
                       meta={"algo": "ppo"})
    trainer = PopTrainer(agent, pcfg, telemetry=tel)
    ...
    tel.close()

``tools/report.py`` replays the JSONL into a PBT family tree, per-member
hyper trajectories, per-phase timing and compile counts; see
``docs/observability.md``.
"""
from repro.telemetry.latency import LatencyWindow
from repro.telemetry.run import RunTelemetry, make_telemetry
from repro.telemetry.sink import (CSVSink, ConsoleSink, JSONLSink,
                                  MetricsSink, MultiSink, NullSink,
                                  ROW_KINDS, jsonable, validate_row)

__all__ = [
    "CSVSink", "ConsoleSink", "JSONLSink", "LatencyWindow", "MetricsSink",
    "MultiSink", "NullSink", "ROW_KINDS", "RunTelemetry", "jsonable",
    "make_telemetry", "validate_row",
]
