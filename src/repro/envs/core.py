"""Pure-JAX environments (Brax-style), fully vmappable.

MuJoCo/Atari are unavailable here and CPU-bound anyway; following the paper's
own §4 recommendation ("simulators with built-in support for hardware
accelerators ... must be used"), physics are implemented in ``jax.lax`` so
both data collection *and* updates vectorize over the population on one
accelerator.

API (functional):
    env = make("pendulum")
    state, obs = env.reset(key)
    state, obs, reward, done, truncated = env.step(state, action)
    policy_input = env.observe(state)

Env step functions report only true TERMINATION (cartpole falling,
mountain-car reaching the goal, acrobot swinging up); the ``make`` wrapper
adds the ``spec.episode_length`` time limit as TRUNCATION and auto-resets on
either (state carries its own rng).  ``done = terminated | truncated`` ends
the episode, but TD targets must bootstrap THROUGH a truncation — only
``done & ~truncated`` belongs in a replay transition's ``done`` field
(``VecEnv``/``rollout`` store it that way).

Terminal-observation contract: on a ``done`` step, the ``obs`` returned by
``step`` is the observation of the **pre-reset terminal state** (the
correct ``next_obs`` for TD bootstrapping), while the returned state has
already been reset — so the next policy input must come from
``env.observe(state)``, never from the returned ``obs``.  ``rollout`` and
``repro.rollout.VecEnv`` both follow this protocol; mixing the two
observations up is exactly the cross-episode-bootstrapping bug the
regression tests in ``tests/test_rollout.py`` pin down.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.envs.hopper2d import (_hopper2d_obs, _hopper2d_reset,
                                 _hopper2d_step)


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    act_dim: int            # continuous dims, or number of discrete actions
    discrete: bool
    episode_length: int
    act_limit: float = 1.0


@dataclass(frozen=True)
class Env:
    spec: EnvSpec
    reset: Callable         # key -> (state, obs)
    step: Callable          # (state, action) ->
                            #   (state, obs, reward, done, truncated)
    observe: Callable       # state -> obs (post-auto-reset policy input)


# ---------------------------------------------------------------------------
# pendulum (continuous; the HalfCheetah stand-in for SAC/TD3 studies)
# ---------------------------------------------------------------------------

_PEND = dict(max_speed=8.0, max_torque=2.0, dt=0.05, g=10.0, m=1.0, l=1.0)


def _pendulum_obs(s):
    th, thdot = s["theta"], s["thetadot"]
    return jnp.stack([jnp.cos(th), jnp.sin(th), thdot / _PEND["max_speed"]], -1)


def _pendulum_reset(key):
    k1, k2, k3 = jax.random.split(key, 3)
    state = {
        "theta": jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi),
        "thetadot": jax.random.uniform(k2, (), minval=-1.0, maxval=1.0),
        "t": jnp.zeros((), jnp.int32),
        "key": k3,
    }
    return state, _pendulum_obs(state)


def _pendulum_step(state, action):
    u = jnp.clip(action[..., 0] * _PEND["max_torque"],
                 -_PEND["max_torque"], _PEND["max_torque"])
    th, thdot = state["theta"], state["thetadot"]
    norm_th = ((th + jnp.pi) % (2 * jnp.pi)) - jnp.pi
    cost = norm_th ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
    g, m, l, dt = (_PEND[k] for k in ("g", "m", "l", "dt"))
    thdot = thdot + (3 * g / (2 * l) * jnp.sin(th) + 3.0 / (m * l ** 2) * u) * dt
    thdot = jnp.clip(thdot, -_PEND["max_speed"], _PEND["max_speed"])
    th = th + thdot * dt
    new = dict(state, theta=th, thetadot=thdot, t=state["t"] + 1)
    # never terminates; episodes end by the wrapper's time-limit truncation
    return new, _pendulum_obs(new), -cost / 10.0, jnp.zeros((), bool)


# ---------------------------------------------------------------------------
# reacher (continuous point-mass reaching; the Humanoid stand-in for DvD)
# ---------------------------------------------------------------------------


def _reacher_obs(s):
    return jnp.concatenate([s["pos"], s["vel"], s["target"] - s["pos"]], -1)


def _reacher_reset(key):
    k1, k2 = jax.random.split(key)
    state = {
        "pos": jnp.zeros((2,)), "vel": jnp.zeros((2,)),
        "target": jax.random.uniform(k1, (2,), minval=-1.0, maxval=1.0),
        "t": jnp.zeros((), jnp.int32), "key": k2,
    }
    return state, _reacher_obs(state)


def _reacher_step(state, action):
    a = jnp.clip(action, -1.0, 1.0)
    vel = 0.9 * state["vel"] + 0.1 * a
    pos = jnp.clip(state["pos"] + 0.1 * vel, -2.0, 2.0)
    dist = jnp.linalg.norm(pos - state["target"])
    reward = -dist - 0.01 * jnp.sum(a ** 2)
    new = dict(state, pos=pos, vel=vel, t=state["t"] + 1)
    return new, _reacher_obs(new), reward, jnp.zeros((), bool)


# ---------------------------------------------------------------------------
# cartpole (discrete; the Atari stand-in for DQN)
# ---------------------------------------------------------------------------


def _cartpole_obs(s):
    return s["x"]


def _cartpole_reset(key):
    k1, k2 = jax.random.split(key)
    state = {"x": jax.random.uniform(k1, (4,), minval=-0.05, maxval=0.05),
             "t": jnp.zeros((), jnp.int32), "key": k2}
    return state, _cartpole_obs(state)


def _cartpole_step(state, action):
    gravity, mc, mp, lp, fmag, dt = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02
    x, xd, th, thd = (state["x"][i] for i in range(4))
    force = jnp.where(action.astype(jnp.int32) == 1, fmag, -fmag)
    cth, sth = jnp.cos(th), jnp.sin(th)
    tmp = (force + mp * lp * thd ** 2 * sth) / (mc + mp)
    thacc = (gravity * sth - cth * tmp) / (lp * (4.0 / 3 - mp * cth ** 2 / (mc + mp)))
    xacc = tmp - mp * lp * thacc * cth / (mc + mp)
    nx = jnp.stack([x + dt * xd, xd + dt * xacc, th + dt * thd, thd + dt * thacc])
    fail = (jnp.abs(nx[0]) > 2.4) | (jnp.abs(nx[2]) > 0.2095)
    reward = 1.0 - fail.astype(jnp.float32)
    new = dict(state, x=nx, t=state["t"] + 1)
    return new, _cartpole_obs(new), reward, fail


# ---------------------------------------------------------------------------
# mountain_car (continuous; sparse-reward exploration scenario)
# ---------------------------------------------------------------------------

_MC = dict(power=0.0015, min_pos=-1.2, max_pos=0.6, max_speed=0.07,
           goal_pos=0.45)


def _mountain_car_obs(s):
    return jnp.stack([s["pos"], s["vel"]], -1)


def _mountain_car_reset(key):
    k1, k2 = jax.random.split(key)
    state = {"pos": jax.random.uniform(k1, (), minval=-0.6, maxval=-0.4),
             "vel": jnp.zeros(()),
             "t": jnp.zeros((), jnp.int32), "key": k2}
    return state, _mountain_car_obs(state)


def _mountain_car_step(state, action):
    force = jnp.clip(action[..., 0], -1.0, 1.0)
    vel = state["vel"] + force * _MC["power"] - 0.0025 * jnp.cos(3 * state["pos"])
    vel = jnp.clip(vel, -_MC["max_speed"], _MC["max_speed"])
    pos = jnp.clip(state["pos"] + vel, _MC["min_pos"], _MC["max_pos"])
    vel = jnp.where((pos <= _MC["min_pos"]) & (vel < 0), 0.0, vel)
    goal = pos >= _MC["goal_pos"]
    reward = 100.0 * goal.astype(jnp.float32) - 0.1 * force ** 2
    new = dict(state, pos=pos, vel=vel, t=state["t"] + 1)
    return new, _mountain_car_obs(new), reward, goal


# ---------------------------------------------------------------------------
# acrobot (discrete, 3 actions; the harder DQN scenario — 2-link swing-up)
# ---------------------------------------------------------------------------

_ACRO = dict(m=1.0, l=1.0, lc=0.5, i=1.0, g=9.8, dt=0.2,
             max_vel1=4 * jnp.pi, max_vel2=9 * jnp.pi)


def _acrobot_obs(s):
    th1, th2, d1, d2 = (s["q"][i] for i in range(4))
    return jnp.stack([jnp.cos(th1), jnp.sin(th1), jnp.cos(th2), jnp.sin(th2),
                      d1 / _ACRO["max_vel1"], d2 / _ACRO["max_vel2"]], -1)


def _acrobot_reset(key):
    k1, k2 = jax.random.split(key)
    state = {"q": jax.random.uniform(k1, (4,), minval=-0.1, maxval=0.1),
             "t": jnp.zeros((), jnp.int32), "key": k2}
    return state, _acrobot_obs(state)


def _acrobot_dsdt(q, torque):
    m, l, lc, i, g = (_ACRO[k] for k in ("m", "l", "lc", "i", "g"))
    th1, th2, dth1, dth2 = q[0], q[1], q[2], q[3]
    d1 = m * lc ** 2 + m * (l ** 2 + lc ** 2 + 2 * l * lc * jnp.cos(th2)) + 2 * i
    d2 = m * (lc ** 2 + l * lc * jnp.cos(th2)) + i
    phi2 = m * lc * g * jnp.cos(th1 + th2 - jnp.pi / 2)
    phi1 = (-m * l * lc * dth2 ** 2 * jnp.sin(th2)
            - 2 * m * l * lc * dth2 * dth1 * jnp.sin(th2)
            + (m * lc + m * l) * g * jnp.cos(th1 - jnp.pi / 2) + phi2)
    ddth2 = ((torque + d2 / d1 * phi1 - m * l * lc * dth1 ** 2 * jnp.sin(th2)
              - phi2) / (m * lc ** 2 + i - d2 ** 2 / d1))
    ddth1 = -(d2 * ddth2 + phi1) / d1
    return jnp.stack([dth1, dth2, ddth1, ddth2])


def _acrobot_step(state, action):
    torque = action.astype(jnp.float32) - 1.0   # {0,1,2} -> {-1,0,+1}
    q, dt = state["q"], _ACRO["dt"]
    # RK4 over the continuous dynamics (gym's integrator)
    k1 = _acrobot_dsdt(q, torque)
    k2 = _acrobot_dsdt(q + dt / 2 * k1, torque)
    k3 = _acrobot_dsdt(q + dt / 2 * k2, torque)
    k4 = _acrobot_dsdt(q + dt * k3, torque)
    nq = q + dt / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
    wrap = lambda x: ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi
    nq = jnp.stack([wrap(nq[0]), wrap(nq[1]),
                    jnp.clip(nq[2], -_ACRO["max_vel1"], _ACRO["max_vel1"]),
                    jnp.clip(nq[3], -_ACRO["max_vel2"], _ACRO["max_vel2"])])
    solved = -jnp.cos(nq[0]) - jnp.cos(nq[1] + nq[0]) > 1.0
    reward = jnp.where(solved, 0.0, -1.0)
    new = dict(state, q=nq, t=state["t"] + 1)
    return new, _acrobot_obs(new), reward, solved


# ---------------------------------------------------------------------------


def _with_auto_reset(reset_fn, raw_step, episode_length: int):
    """Generic time limit + auto-reset.  The raw step reports only true
    termination; the wrapper adds ``spec.episode_length`` truncation and
    resets on either.  The returned ``obs`` stays the pre-reset terminal
    observation (the transition's correct ``next_obs``); the returned state
    is reset where the episode ended so the loop continues fresh."""
    def step(state, action):
        new, obs, reward, terminated = raw_step(state, action)
        truncated = ~terminated & (new["t"] >= episode_length)
        done = terminated | truncated
        k_next, k_reset = jax.random.split(new["key"])
        fresh, _ = reset_fn(k_reset)
        fresh = dict(fresh, key=k_next)
        new = dict(new, key=k_next)
        state = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, new)
        return state, obs, reward, done, truncated
    return step


_REGISTRY = {
    "pendulum": (EnvSpec("pendulum", 3, 1, False, 200, 1.0),
                 _pendulum_reset, _pendulum_step, _pendulum_obs),
    "reacher": (EnvSpec("reacher", 6, 2, False, 100, 1.0),
                _reacher_reset, _reacher_step, _reacher_obs),
    "cartpole": (EnvSpec("cartpole", 4, 2, True, 500),
                 _cartpole_reset, _cartpole_step, _cartpole_obs),
    "mountain_car": (EnvSpec("mountain_car", 2, 1, False, 200, 1.0),
                     _mountain_car_reset, _mountain_car_step,
                     _mountain_car_obs),
    "acrobot": (EnvSpec("acrobot", 6, 3, True, 500),
                _acrobot_reset, _acrobot_step, _acrobot_obs),
    # the physics tier (repro.envs.hopper2d): rigid-body planar hopper,
    # expensive enough per step that GPU-sim-scale acting is real work
    "hopper2d": (EnvSpec("hopper2d", 11, 3, False, 400, 1.0),
                 _hopper2d_reset, _hopper2d_step, _hopper2d_obs),
}


def make(name: str) -> Env:
    spec, reset, raw_step, observe = _REGISTRY[name]
    return Env(spec=spec, reset=reset,
               step=_with_auto_reset(reset, raw_step, spec.episode_length),
               observe=observe)


def rollout(env: Env, policy_fn, params, key, num_steps: int):
    """Collect a trajectory with a jitted scan. policy_fn(params, obs, key).

    Follows the terminal-observation contract: on a done step ``next_obs``
    is the pre-reset terminal observation, and the *next* transition's
    ``obs`` is the post-reset ``env.observe(state)`` — no transition ever
    straddles an episode boundary.  The stored ``done`` is termination only
    (``done & ~truncated``): TD targets bootstrap through time limits.
    """
    state, obs = env.reset(key)

    def body(carry, _):
        state, obs = carry
        k = state["key"]
        ka, _ = jax.random.split(k)
        action = policy_fn(params, obs, ka)
        nstate, terminal_obs, reward, done, truncated = env.step(state, action)
        trans = {"obs": obs, "action": action, "reward": reward,
                 "next_obs": terminal_obs,
                 "done": (done & ~truncated).astype(jnp.float32)}
        return (nstate, env.observe(nstate)), trans

    (_, _), traj = jax.lax.scan(body, (state, obs), None, length=num_steps)
    return traj
