"""Pure-JAX environments (Brax-style), fully vmappable.

MuJoCo/Atari are unavailable here and CPU-bound anyway; following the paper's
own §4 recommendation ("simulators with built-in support for hardware
accelerators ... must be used"), physics are implemented in ``jax.lax`` so
both data collection *and* updates vectorize over the population on one
accelerator.

API (functional):
    env = make("pendulum")
    state, obs = env.reset(key)
    state, obs, reward, done = env.step(state, action)
Auto-reset on ``done`` is built into ``step`` (state carries its own rng).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    act_dim: int            # continuous dims, or number of discrete actions
    discrete: bool
    episode_length: int
    act_limit: float = 1.0


@dataclass(frozen=True)
class Env:
    spec: EnvSpec
    reset: Callable
    step: Callable


# ---------------------------------------------------------------------------
# pendulum (continuous; the HalfCheetah stand-in for SAC/TD3 studies)
# ---------------------------------------------------------------------------

_PEND = dict(max_speed=8.0, max_torque=2.0, dt=0.05, g=10.0, m=1.0, l=1.0)


def _pendulum_obs(s):
    th, thdot = s["theta"], s["thetadot"]
    return jnp.stack([jnp.cos(th), jnp.sin(th), thdot / _PEND["max_speed"]], -1)


def _pendulum_reset(key):
    k1, k2, k3 = jax.random.split(key, 3)
    state = {
        "theta": jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi),
        "thetadot": jax.random.uniform(k2, (), minval=-1.0, maxval=1.0),
        "t": jnp.zeros((), jnp.int32),
        "key": k3,
    }
    return state, _pendulum_obs(state)


def _pendulum_step(state, action):
    u = jnp.clip(action[..., 0] * _PEND["max_torque"],
                 -_PEND["max_torque"], _PEND["max_torque"])
    th, thdot = state["theta"], state["thetadot"]
    norm_th = ((th + jnp.pi) % (2 * jnp.pi)) - jnp.pi
    cost = norm_th ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
    g, m, l, dt = (_PEND[k] for k in ("g", "m", "l", "dt"))
    thdot = thdot + (3 * g / (2 * l) * jnp.sin(th) + 3.0 / (m * l ** 2) * u) * dt
    thdot = jnp.clip(thdot, -_PEND["max_speed"], _PEND["max_speed"])
    th = th + thdot * dt
    t = state["t"] + 1
    done = t >= 200
    new = dict(state, theta=th, thetadot=thdot, t=t)
    return _auto_reset(_pendulum_reset, new, done), _pendulum_obs(new), \
        -cost / 10.0, done


# ---------------------------------------------------------------------------
# reacher (continuous point-mass reaching; the Humanoid stand-in for DvD)
# ---------------------------------------------------------------------------


def _reacher_obs(s):
    return jnp.concatenate([s["pos"], s["vel"], s["target"] - s["pos"]], -1)


def _reacher_reset(key):
    k1, k2 = jax.random.split(key)
    state = {
        "pos": jnp.zeros((2,)), "vel": jnp.zeros((2,)),
        "target": jax.random.uniform(k1, (2,), minval=-1.0, maxval=1.0),
        "t": jnp.zeros((), jnp.int32), "key": k2,
    }
    return state, _reacher_obs(state)


def _reacher_step(state, action):
    a = jnp.clip(action, -1.0, 1.0)
    vel = 0.9 * state["vel"] + 0.1 * a
    pos = jnp.clip(state["pos"] + 0.1 * vel, -2.0, 2.0)
    dist = jnp.linalg.norm(pos - state["target"])
    reward = -dist - 0.01 * jnp.sum(a ** 2)
    t = state["t"] + 1
    done = t >= 100
    new = dict(state, pos=pos, vel=vel, t=t)
    return _auto_reset(_reacher_reset, new, done), _reacher_obs(new), reward, done


# ---------------------------------------------------------------------------
# cartpole (discrete; the Atari stand-in for DQN)
# ---------------------------------------------------------------------------


def _cartpole_obs(s):
    return s["x"]


def _cartpole_reset(key):
    k1, k2 = jax.random.split(key)
    state = {"x": jax.random.uniform(k1, (4,), minval=-0.05, maxval=0.05),
             "t": jnp.zeros((), jnp.int32), "key": k2}
    return state, _cartpole_obs(state)


def _cartpole_step(state, action):
    gravity, mc, mp, lp, fmag, dt = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02
    x, xd, th, thd = (state["x"][i] for i in range(4))
    force = jnp.where(action.astype(jnp.int32) == 1, fmag, -fmag)
    cth, sth = jnp.cos(th), jnp.sin(th)
    tmp = (force + mp * lp * thd ** 2 * sth) / (mc + mp)
    thacc = (gravity * sth - cth * tmp) / (lp * (4.0 / 3 - mp * cth ** 2 / (mc + mp)))
    xacc = tmp - mp * lp * thacc * cth / (mc + mp)
    nx = jnp.stack([x + dt * xd, xd + dt * xacc, th + dt * thd, thd + dt * thacc])
    t = state["t"] + 1
    fail = (jnp.abs(nx[0]) > 2.4) | (jnp.abs(nx[2]) > 0.2095)
    done = fail | (t >= 500)
    reward = 1.0 - fail.astype(jnp.float32)
    new = dict(state, x=nx, t=t)
    return _auto_reset(_cartpole_reset, new, done), _cartpole_obs(new), reward, done


# ---------------------------------------------------------------------------


def _auto_reset(reset_fn, state, done):
    k_next, k_reset = jax.random.split(state["key"])
    fresh, _ = reset_fn(k_reset)
    fresh = dict(fresh, key=k_next)
    state = dict(state, key=k_next)
    return jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, state)


_REGISTRY = {
    "pendulum": (EnvSpec("pendulum", 3, 1, False, 200, 1.0),
                 _pendulum_reset, _pendulum_step),
    "reacher": (EnvSpec("reacher", 6, 2, False, 100, 1.0),
                _reacher_reset, _reacher_step),
    "cartpole": (EnvSpec("cartpole", 4, 2, True, 500),
                 _cartpole_reset, _cartpole_step),
}


def make(name: str) -> Env:
    spec, reset, step = _REGISTRY[name]
    return Env(spec=spec, reset=reset, step=step)


def rollout(env: Env, policy_fn, params, key, num_steps: int):
    """Collect a trajectory with a jitted scan. policy_fn(params, obs, key)."""
    state, obs = env.reset(key)

    def body(carry, _):
        state, obs = carry
        k = state["key"]
        ka, _ = jax.random.split(k)
        action = policy_fn(params, obs, ka)
        nstate, nobs, reward, done = env.step(state, action)
        trans = {"obs": obs, "action": action, "reward": reward,
                 "next_obs": nobs, "done": done.astype(jnp.float32)}
        return (nstate, nobs), trans

    (_, _), traj = jax.lax.scan(body, (state, obs), None, length=num_steps)
    return traj
