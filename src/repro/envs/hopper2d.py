"""hopper2d — a physics-grade pure-JAX rigid-body env (Brax-style).

The classic-control four in :mod:`repro.envs.core` cost a handful of flops
per step, which makes acting nearly free and hides the collect/update
overlap question the rollout engine's ``policy_lag`` path answers.  This
module adds the tier the paper's §4 GPU-sim argument actually assumes: a
planar hopper simulated as articulated rigid bodies, expensive enough per
step that collecting thousands of envs per member is real device work.

Model (Brax v1 "legacy spring" style, in 2D):

  * **Maximal coordinates** — every body carries its own pose
    ``(pos(x,z), th)`` and velocity ``(vel, om)``; nothing is reduced to
    joint angles.  4 bodies: torso, thigh, leg (rods along their local z
    axis) and foot (a rod along local x).
  * **Joints as spring-dampers** — each revolute joint pins two body-frame
    anchor points together with a stiff spring ``F = k·(pa−pb) + c·(va−vb)``
    (plus relative-angle damping and a soft angle-limit spring) instead of
    solving constraints exactly.  This is what makes the step a closed-form
    ``jnp`` expression: vmappable over envs and members, no LCP solver.
  * **Penalty contacts** — candidate points penetrating ``z<0`` get a
    spring-damper normal force (clamped ≥ 0) and smooth Coulomb friction
    ``-mu·N·tanh(vx/v_s)``.
  * **Semi-implicit Euler** — ``v += dt·F/m`` then ``x += dt·v``, the
    symplectic update Brax's legacy-spring backend uses; ``SUBSTEPS``
    integrator steps per control step.

The dynamics are deliberately expressed as plain array math over the
``(4, ...)`` body axes with all constants in module-level dicts, so the
test wall (``tests/test_hopper_env.py``) can pin the integrator against an
independent pure-Python/numpy re-implementation.

Registered in ``repro.envs.core._REGISTRY`` as ``"hopper2d"`` (continuous,
obs 11, act 3) and wrapped by ``make`` with the usual truncation +
auto-reset contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# body order: 0 torso, 1 thigh, 2 leg, 3 foot
_H2D = dict(
    dt=0.002,            # integrator substep
    substeps=5,          # substeps per control step (control dt = 10 ms)
    gravity=9.8,
    length=(0.40, 0.45, 0.50, 0.39),      # rod lengths
    mass=(3.5, 4.0, 2.7, 5.1),            # ~ gym hopper link masses
    joint_k=4000.0,      # joint anchor spring stiffness
    joint_c=40.0,        # joint anchor damping
    rot_c=2.0,           # relative-angle damping at each joint
    limit_k=60.0,        # soft joint-limit spring (torque / rad)
    torque=(30.0, 30.0, 15.0),            # actuator gains (hip, knee, ankle)
    contact_k=6000.0,    # ground penalty stiffness
    contact_c=30.0,      # ground penalty damping
    friction=0.9,
    v_smooth=0.1,        # tanh friction smoothing velocity
    z_min=0.7,           # torso-height termination
    th_max=1.0,          # torso-angle termination
)

# joints: (parent, parent-frame anchor, child, child-frame anchor,
#          limit_lo, limit_hi) — hip, knee, ankle
_JOINTS = (
    (0, (0.0, -0.20), 1, (0.0, 0.225), -1.0, 1.0),
    (1, (0.0, -0.225), 2, (0.0, 0.25), -1.2, 1.2),
    (2, (0.0, -0.25), 3, (-0.0975, 0.0), -0.8, 0.8),
)

# ground-contact candidate points: (body, body-frame offset)
_CONTACTS = (
    (3, (0.195, 0.0)), (3, (-0.195, 0.0)),    # foot toe / heel
    (2, (0.0, -0.25)),                        # leg bottom (kneeling)
    (0, (0.0, -0.20)), (0, (0.0, 0.20)),      # torso ends (falling over)
)

# upright rest pose: foot hovering at z=0.06, leg/thigh/torso stacked
# vertically above the ankle anchor (all body angles zero)
_REST_POS = ((-0.0975, 1.21), (-0.0975, 0.785), (-0.0975, 0.31), (0.0, 0.06))


def _rot(th, lx, lz):
    """Rotate a body-frame offset into the world frame."""
    c, s = jnp.cos(th), jnp.sin(th)
    return jnp.stack([c * lx - s * lz, s * lx + c * lz], -1)


def _point_vel(vel, om, r):
    """World velocity of a point at world offset ``r`` from the COM:
    v + om × r, with om × (rx, rz) = om·(−rz, rx) in 2D."""
    return vel + om[..., None] * jnp.stack([-r[..., 1], r[..., 0]], -1)


def _cross2(r, f):
    return r[..., 0] * f[..., 1] - r[..., 1] * f[..., 0]


def _hopper2d_forces(pos, th, vel, om, action):
    """Net world force (4, 2) and torque (4,) on every body: gravity +
    spring-damper joints (with actuation, rotational damping and soft
    limits) + penalty ground contacts."""
    m = jnp.asarray(_H2D["mass"])
    f = jnp.zeros((4, 2)).at[:, 1].add(-_H2D["gravity"] * m)
    tau = jnp.zeros((4,))

    for j, (p, ra, c, rb, lo, hi) in enumerate(_JOINTS):
        wa = _rot(th[p], *ra)                    # world anchor offsets
        wb = _rot(th[c], *rb)
        dx = (pos[p] + wa) - (pos[c] + wb)       # anchor separation
        dv = _point_vel(vel[p], om[p], wa) - _point_vel(vel[c], om[c], wb)
        fj = _H2D["joint_k"] * dx + _H2D["joint_c"] * dv   # pulls child to parent
        f = f.at[c].add(fj).at[p].add(-fj)
        tau = tau.at[c].add(_cross2(wb, fj)).at[p].add(_cross2(wa, -fj))
        # actuation + relative-angle damping + soft limits (child +, parent −)
        rel = th[c] - th[p]
        tj = (_H2D["torque"][j] * action[j]
              - _H2D["rot_c"] * (om[c] - om[p])
              - _H2D["limit_k"] * (jnp.maximum(rel - hi, 0.0)
                                   + jnp.minimum(rel - lo, 0.0)))
        tau = tau.at[c].add(tj).at[p].add(-tj)

    for b, off in _CONTACTS:
        r = _rot(th[b], *off)
        p_w = pos[b] + r
        v_w = _point_vel(vel[b], om[b], r)
        pen = jnp.maximum(-p_w[1], 0.0)
        active = (pen > 0.0).astype(jnp.float32)
        fn = jnp.maximum(
            _H2D["contact_k"] * pen - _H2D["contact_c"] * v_w[1], 0.0) * active
        ft = -_H2D["friction"] * fn * jnp.tanh(v_w[0] / _H2D["v_smooth"])
        fc = jnp.stack([ft, fn], -1)
        f = f.at[b].add(fc)
        tau = tau.at[b].add(_cross2(r, fc))
    return f, tau


def _hopper2d_obs(s):
    th, om = s["th"], s["om"]
    return jnp.concatenate([
        jnp.stack([s["pos"][0, 1], th[0], th[1] - th[0], th[2] - th[1],
                   th[3] - th[2]]),
        s["vel"][0],
        jnp.stack([om[0], om[1] - om[0], om[2] - om[1], om[3] - om[2]]),
    ])


def _hopper2d_reset(key):
    k1, k2, k3 = jax.random.split(key, 3)
    state = {
        "pos": jnp.asarray(_REST_POS)
        + jax.random.uniform(k1, (4, 2), minval=-5e-3, maxval=5e-3),
        "th": jax.random.uniform(k2, (4,), minval=-5e-3, maxval=5e-3),
        "vel": jnp.zeros((4, 2)),
        "om": jnp.zeros((4,)),
        "t": jnp.zeros((), jnp.int32),
        "key": k3,
    }
    return state, _hopper2d_obs(state)


def _hopper2d_step(state, action):
    a = jnp.clip(action, -1.0, 1.0)
    m = jnp.asarray(_H2D["mass"])
    L = jnp.asarray(_H2D["length"])
    inertia = m * L ** 2 / 12.0      # thin rod about its center
    dt = _H2D["dt"]

    def substep(carry, _):
        pos, th, vel, om = carry
        f, tau = _hopper2d_forces(pos, th, vel, om, a)
        vel = vel + dt * f / m[:, None]      # semi-implicit Euler:
        om = om + dt * tau / inertia         # velocities first,
        pos = pos + dt * vel                 # then positions from the
        th = th + dt * om                    # NEW velocities
        return (pos, th, vel, om), None

    (pos, th, vel, om), _ = jax.lax.scan(
        substep, (state["pos"], state["th"], state["vel"], state["om"]),
        None, length=_H2D["substeps"])

    fwd = (pos[0, 0] - state["pos"][0, 0]) / (dt * _H2D["substeps"])
    reward = fwd + 1.0 - 1e-3 * jnp.sum(a ** 2)
    new = dict(state, pos=pos, th=th, vel=vel, om=om, t=state["t"] + 1)
    terminated = (pos[0, 1] < _H2D["z_min"]) | (jnp.abs(th[0]) > _H2D["th_max"])
    return new, _hopper2d_obs(new), reward, terminated
