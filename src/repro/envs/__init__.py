from repro.envs.core import Env, EnvSpec, make, rollout  # noqa: F401
