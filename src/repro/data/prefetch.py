"""Host-side async data plumbing (the paper's Appendix A, in one process).

``Prefetcher`` runs a producer callable on a background thread and keeps a
bounded queue of ready batches, so device update chains never wait on the
host — the paper's requirement that "training data is available ... without
delay whenever an update step has just completed".

``DoubleBuffer`` keeps batch k+1 transferring to device while batch k is
being consumed (classic double-buffering; `jax.device_put` is async).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax


class Prefetcher:
    def __init__(self, producer: Callable[[], object], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: BaseException | None = None

        def run():
            try:
                while not self._stop.is_set():
                    item = producer()
                    while not self._stop.is_set():
                        try:
                            self._q.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:  # surfaced on next __next__
                self._exc = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            if self._exc is not None:
                raise self._exc
            try:
                return self._q.get(timeout=0.5)
            except queue.Empty:
                continue

    def close(self):
        self._stop.set()


class DoubleBuffer:
    """Wrap a host-batch iterator; yields device arrays one step ahead."""

    def __init__(self, it: Iterator, device=None):
        self._it = iter(it)
        self._device = device or jax.devices()[0]
        self._next = self._put(next(self._it))

    def _put(self, x):
        return jax.device_put(x, self._device)

    def __iter__(self):
        return self

    def __next__(self):
        out = self._next
        self._next = self._put(next(self._it))
        return out
