"""The experience-pipeline contract: one protocol, two storage disciplines.

The paper's §4 protocol — compile the whole train iteration, vmap it over
members — does not care *what* the iteration does with experience, only
that the experience store is a pytree of device arrays so a population of
stores is the same pytree with a leading member axis.  This module pins
that contract down as :class:`ExperienceOps` and provides the repo's two
implementations:

  * ``replay``     — :mod:`repro.data.replay_buffer`'s FIFO ring (moved
                     behind the protocol, numerics unchanged): off-policy
                     learners (TD3/SAC/DQN) insert transitions and sample
                     uniform batches forever.
  * ``trajectory`` — :class:`TrajectoryBuffer` (this module): on-policy
                     learners (PPO) store ONE fixed-length rollout per
                     iteration — including the extras the acting policy
                     emitted (``log_prob``, ``value``) — compute GAE on
                     device, and consume the whole rollout as shuffled
                     epoch/minibatches before it is overwritten.

``repro.rollout.engine`` dispatches its fused train iteration on the
*agent's* declared ``experience_kind`` (the :class:`repro.pop.Agent`
contract); everything below the dispatch — init, add, export for elastic
re-layout — goes through the ops bundle so the engine never hard-codes a
buffer type again.

Item specs
----------
``transition_spec(env_spec)`` is the replay item (what TD bootstrapping
needs); ``trajectory_spec(env_spec, extras)`` is the on-policy item: the
same transition plus ``truncated`` (GAE must cut the lambda chain at a
time limit while still bootstrapping through it) plus one f32 scalar per
policy extra.  Buffers store exactly the keys their spec declares —
richer transition dicts (the collector emits ``truncated`` and extras
unconditionally) are filtered down on ``add``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.data.replay_buffer import (buffer_add, buffer_can_sample,
                                      buffer_init)


def transition_spec(spec):
    """One replay-buffer item for an env spec (ShapeDtypeStructs)."""
    f32 = jnp.float32
    action = (jax.ShapeDtypeStruct((), jnp.int32) if spec.discrete
              else jax.ShapeDtypeStruct((spec.act_dim,), f32))
    return {"obs": jax.ShapeDtypeStruct((spec.obs_dim,), f32),
            "action": action,
            "reward": jax.ShapeDtypeStruct((), f32),
            "next_obs": jax.ShapeDtypeStruct((spec.obs_dim,), f32),
            "done": jax.ShapeDtypeStruct((), f32)}


def trajectory_spec(spec, extras=("log_prob", "value")):
    """One on-policy rollout step: the transition, the truncation flag
    (episode end that must still bootstrap), and the policy extras."""
    item = dict(transition_spec(spec))
    item["truncated"] = jax.ShapeDtypeStruct((), jnp.float32)
    for name in extras:
        item[name] = jax.ShapeDtypeStruct((), jnp.float32)
    return item


def select_items(batch, spec):
    """Filter a (possibly richer) transition dict down to a spec's keys —
    the storage half of the "store what your spec declares" contract."""
    return {k: batch[k] for k in spec}


# ---------------------------------------------------------------------------
# trajectory buffer: fixed-length on-policy rollouts
# ---------------------------------------------------------------------------


class TrajectoryBuffer(NamedTuple):
    """A fixed-length rollout store for ONE member: leaves ``(T, E, ...)``
    (time-major over ``num_envs`` parallel envs), plus the fill position.
    A population of these is the same pytree with a leading member axis,
    exactly like :class:`repro.data.ReplayBuffer`."""
    data: Any              # pytree; leaves (num_steps, num_envs, ...)
    pos: jnp.ndarray       # () int32 — steps filled so far


def traj_init(num_steps: int, num_envs: int, item_spec) -> TrajectoryBuffer:
    """``item_spec``: pytree of arrays/ShapeDtypeStructs (one step of one
    env, e.g. :func:`trajectory_spec`)."""
    data = jax.tree.map(
        lambda x: jnp.zeros((num_steps, num_envs) + tuple(x.shape), x.dtype),
        item_spec)
    return TrajectoryBuffer(data=data, pos=jnp.zeros((), jnp.int32))


def traj_add(buf: TrajectoryBuffer, steps) -> TrajectoryBuffer:
    """Append ``t`` time-major steps (leaves ``(t, E, ...)``) at the fill
    position.  Extra keys beyond the buffer's spec are dropped; adding past
    capacity overwrites from the start (on-policy consumers drain the
    buffer every iteration, so wrap-around is a caller bug the ``pos``
    accounting makes visible)."""
    if isinstance(buf.data, dict) and isinstance(steps, dict):
        steps = select_items(steps, buf.data)
    t = jax.tree.leaves(steps)[0].shape[0]
    T = jax.tree.leaves(buf.data)[0].shape[0]
    pos = buf.pos % T

    def ins(store, items):
        return jax.lax.dynamic_update_slice_in_dim(
            store, items.astype(store.dtype), pos, axis=0)

    return TrajectoryBuffer(data=jax.tree.map(ins, buf.data, steps),
                            pos=buf.pos + t)


def traj_full(buf: TrajectoryBuffer):
    return buf.pos >= jax.tree.leaves(buf.data)[0].shape[0]


def traj_reset(buf: TrajectoryBuffer) -> TrajectoryBuffer:
    """Rewind the fill position (the data is dead; the next add overwrites).
    On-policy iterations reset before every collect."""
    return TrajectoryBuffer(data=buf.data, pos=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# GAE — on-device, vmappable over members
# ---------------------------------------------------------------------------


def compute_gae(reward, value, next_value, done, ep_end, discount, lam):
    """Generalized Advantage Estimation over a time-major rollout.

    All array args are ``(T, ...)`` (trailing env axes broadcast through);
    ``discount`` / ``lam`` are scalars (per-member hypers under ``vmap``).

        delta_t = r_t + discount * V(s'_t) * (1 - done_t) - V(s_t)
        A_t     = delta_t + discount * lam * (1 - ep_end_t) * A_{t+1}

    The two masks are deliberately different (the repo's truncation
    contract, see ``repro.envs.core``): ``done`` is TERMINATION only, so a
    time-limit step still bootstraps from ``next_value`` (the value of the
    pre-reset terminal observation); ``ep_end`` is termination OR
    truncation, so the lambda chain never leaks across an episode boundary
    — the auto-reset means step t+1 belongs to a fresh episode.

    Returns ``(advantages, returns)`` with ``returns = advantages + value``
    (the lambda-return value target).
    """
    def body(carry, xs):
        r, v, nv, d, e = xs
        delta = r + discount * nv * (1.0 - d) - v
        adv = delta + discount * lam * (1.0 - e) * carry
        return adv, adv

    _, adv = jax.lax.scan(body, jnp.zeros_like(reward[0]),
                          (reward, value, next_value, done, ep_end),
                          reverse=True)
    return adv, adv + value


# ---------------------------------------------------------------------------
# the ops bundle (protocol instance per experience kind)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExperienceOps:
    """The uniform half of the experience contract — what the rollout
    engine (and elastic re-layout) can do to ANY buffer without knowing its
    kind.  The non-uniform half (how stored experience becomes update
    batches: uniform replay sampling vs GAE + shuffled epoch/minibatches)
    is exactly why ``repro.rollout.engine`` builds a different fused
    iteration per kind.

    ``init(env_spec, **cfg) -> buf`` builds ONE member's buffer (engines
    vmap it); ``add(buf, items) -> buf`` stores one collect's output
    (filtered to the spec — appended FIFO for replay, REPLACING the rollout
    for trajectory, whose data lives exactly one iteration);
    ``ready(buf, batch_size) -> bool`` gates updates (a replay ring must
    hold a batch; a trajectory buffer must hold the full rollout).
    """
    kind: str
    init: Callable
    add: Callable
    ready: Callable
    item_spec: Callable


def _replay_init(env_spec, *, capacity: int, **_):
    return buffer_init(capacity, transition_spec(env_spec))


def _trajectory_init(env_spec, *, num_steps: int, num_envs: int,
                     extras=("log_prob", "value"), **_):
    return traj_init(num_steps, num_envs, trajectory_spec(env_spec, extras))


def _trajectory_store(buf, steps):
    """One iteration's rollout replaces the last one (the previous data is
    off-policy the moment the update ran); incremental filling is still
    available via ``traj_add`` directly."""
    return traj_add(traj_reset(buf), steps)


EXPERIENCE_KINDS = {
    "replay": ExperienceOps(kind="replay", init=_replay_init, add=buffer_add,
                            ready=buffer_can_sample,
                            item_spec=transition_spec),
    "trajectory": ExperienceOps(kind="trajectory", init=_trajectory_init,
                                add=_trajectory_store,
                                ready=lambda buf, _=None: traj_full(buf),
                                item_spec=trajectory_spec),
}


def experience_ops(kind: str) -> ExperienceOps:
    ops = EXPERIENCE_KINDS.get(kind)
    if ops is None:
        raise ValueError(f"unknown experience kind {kind!r}; registered: "
                         f"{sorted(EXPERIENCE_KINDS)}")
    return ops
