"""Deterministic synthetic LM token pipeline with per-shard streams.

Real corpora are unavailable offline; training drivers consume a seeded
synthetic stream whose statistics (Zipfian unigram + short-range structure)
exercise the full embedding table and give a non-degenerate loss curve.
Sharding: each data-parallel rank derives an independent, restart-stable
stream from (seed, shard_index, step), which is exactly the contract a real
tokenized-corpus loader must satisfy for elastic restarts.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_stream(vocab: int, seed: int, shard: int, num_shards: int):
    """Infinite generator of token ids (Zipf + Markov structure)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, shard]))
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    prev = 0
    while True:
        block = rng.choice(vocab, size=8192, p=probs)
        # short-range structure: every 4th token repeats prev (gives the model
        # something learnable in a few hundred steps)
        block[::4] = np.roll(block, 1)[::4]
        yield from block.astype(np.int32)


def host_batches(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
                 shard: int = 0, num_shards: int = 1, start_step: int = 0):
    """Yield (batch, seq_len) int32 arrays; resumable via ``start_step``."""
    streams = [synthetic_token_stream(vocab, seed, shard * batch + i, num_shards * batch)
               for i in range(batch)]
    # fast-forward for restart stability
    for s in streams:
        for _ in range(start_step * seq_len):
            next(s)
    while True:
        yield np.stack([np.fromiter(s, np.int32, seq_len) for s in streams])
