"""Device-resident FIFO replay buffer (functional pytree, vmappable).

The paper (Appendix A) hosts one replay buffer per agent in the process that
owns the accelerator.  Here the buffer is itself a pytree of device arrays so
an entire *population* of buffers is just this pytree with a leading
population axis — inserts and samples vmap across members exactly like the
update steps do, and buffer donation (``donate_argnums``) makes inserts
in-place on device.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ReplayBuffer(NamedTuple):
    data: Any              # pytree; leaves (capacity, ...)
    insert_pos: jnp.ndarray
    total: jnp.ndarray     # number of items ever added


def buffer_init(capacity: int, sample_transition) -> ReplayBuffer:
    """``sample_transition``: pytree of arrays/ShapeDtypeStructs (one item)."""
    data = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + tuple(x.shape), x.dtype),
        sample_transition)
    return ReplayBuffer(data=data, insert_pos=jnp.zeros((), jnp.int32),
                        total=jnp.zeros((), jnp.int32))


def buffer_add(buf: ReplayBuffer, batch) -> ReplayBuffer:
    """Insert a batch (leading axis n) at the ring position (FIFO).

    The buffer stores exactly the keys its init spec declared: a richer
    transition dict (the collector also emits ``truncated`` and on-policy
    extras — see ``repro.data.experience``) is filtered down, so one
    collect path feeds every experience kind."""
    if isinstance(buf.data, dict) and isinstance(batch, dict):
        batch = {k: batch[k] for k in buf.data}
    n = jax.tree.leaves(batch)[0].shape[0]
    capacity = jax.tree.leaves(buf.data)[0].shape[0]
    idx = (buf.insert_pos + jnp.arange(n)) % capacity

    def ins(store, items):
        return store.at[idx].set(items.astype(store.dtype))

    return ReplayBuffer(
        data=jax.tree.map(ins, buf.data, batch),
        insert_pos=(buf.insert_pos + n) % capacity,
        total=buf.total + n)


def buffer_can_sample(buf: ReplayBuffer, batch_size: int):
    return buf.total >= batch_size


def buffer_sample(buf: ReplayBuffer, key, batch_size: int):
    """Uniform sample of ``batch_size`` stored items (with replacement).

    Sampling an empty buffer is a bug (it would return the all-zero
    initialization as if it were data): callers inside ``jit``/``vmap`` must
    gate on ``buffer_can_sample`` (the fused train iteration in
    ``repro.rollout.engine`` does); eagerly we can and do refuse outright.
    """
    if not isinstance(buf.total, jax.core.Tracer) and int(buf.total) == 0:
        raise ValueError(
            "buffer_sample called on an empty buffer; gate on "
            "buffer_can_sample(buf, batch_size) first")
    capacity = jax.tree.leaves(buf.data)[0].shape[0]
    limit = jnp.minimum(buf.total, capacity)
    idx = jax.random.randint(key, (batch_size,), 0, jnp.maximum(limit, 1))
    return jax.tree.map(lambda store: store[idx], buf.data)
