from repro.data.replay_buffer import (  # noqa: F401
    ReplayBuffer, buffer_init, buffer_add, buffer_sample, buffer_can_sample,
)
from repro.data.prefetch import Prefetcher, DoubleBuffer  # noqa: F401
from repro.data.lm_pipeline import synthetic_token_stream, host_batches  # noqa: F401
