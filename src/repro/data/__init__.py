# The experience pipeline: one protocol (ExperienceOps), two storage
# disciplines — the off-policy FIFO replay ring and the on-policy
# fixed-length trajectory store with on-device GAE.
from repro.data.replay_buffer import (  # noqa: F401
    ReplayBuffer, buffer_init, buffer_add, buffer_sample, buffer_can_sample,
)
from repro.data.experience import (  # noqa: F401
    ExperienceOps, EXPERIENCE_KINDS, experience_ops,
    TrajectoryBuffer, traj_init, traj_add, traj_full, traj_reset,
    compute_gae, transition_spec, trajectory_spec, select_items,
)
from repro.data.prefetch import Prefetcher, DoubleBuffer  # noqa: F401
from repro.data.lm_pipeline import synthetic_token_stream, host_batches  # noqa: F401
