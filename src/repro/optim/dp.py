"""Data-parallel gradient reduction with int8 error-feedback compression.

``compressed_psum_tree`` runs inside ``shard_map`` over the data axis: each
rank quantizes its local gradient to int8 (+ one fp32 scale per tensor),
all-gathers the int8 payloads (wire bytes = N x size x 1B instead of the
~2 x size x 4B of a ring fp32 all-reduce), decompresses and sums locally.
Quantization error is fed back into the next step (error feedback keeps
Adam/SGD convergence — Karimireddy et al., 2019; validated in
tests/test_checkpoint_optim.py and tests/test_dp_compression.py).

``make_dp_update`` wraps a single-rank update_fn into a shard_map'd
data-parallel update with either plain psum or compressed reduction —
selected by ``TrainConfig.grad_compression``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.optim.compress import compress_tree, decompress_tree


def compressed_psum_tree(grads, error, axis: str):
    """Inside shard_map: returns (mean_grads, new_error)."""
    q, s, new_error = compress_tree(grads, error)
    n = jax.lax.psum(1, axis)

    def reduce_one(qi, si):
        gq = jax.lax.all_gather(qi, axis)            # (N, ...) int8
        gs = jax.lax.all_gather(si, axis)            # (N,) fp32
        return jnp.tensordot(gs, gq.astype(jnp.float32), axes=(0, 0)) / n

    mean = jax.tree.map(reduce_one, q, s)
    return mean, new_error


def plain_psum_tree(grads, axis: str):
    n = jax.lax.psum(1, axis)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, grads)


def make_dp_update(grad_fn, opt_update, mesh, *, axis: str = "data",
                   compression: str = "none"):
    """grad_fn(params, batch) -> (loss, grads) computed on the local shard.

    Returns ``update(params, opt_state, error, batch) ->
    (params, opt_state, error, loss)`` with params replicated and the batch
    sharded over ``axis``.
    """
    from repro.optim import apply_updates

    def local_update(params, opt_state, error, batch):
        loss, grads = grad_fn(params, batch)
        if compression == "int8":
            grads, error = compressed_psum_tree(grads, error, axis)
        else:
            grads = plain_psum_tree(grads, axis)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, error, jax.lax.pmean(loss, axis)

    spec_rep = P()
    spec_data = P(axis)
    return jax.jit(compat.shard_map(
        local_update, mesh=mesh,
        in_specs=(spec_rep, spec_rep, spec_rep, spec_data),
        out_specs=(spec_rep, spec_rep, spec_rep, spec_rep)))
