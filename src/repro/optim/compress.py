"""Int8 error-feedback gradient compression for data-parallel all-reduce.

Beyond-paper distributed-optimization trick: before the DP gradient
reduction, gradients are quantized per-tensor to int8 with a fp32 scale; the
quantization error is fed back into the next step's gradient (error
feedback), which keeps SGD/Adam convergence (Karimireddy et al., 2019).
Inside ``shard_map`` the int8 tensors are what crosses the ICI links, cutting
the collective term of the roofline by ~4x vs fp32 (2x vs bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error):
    """Quantize grads+error; returns (q_tree, scale_tree, new_error_tree)."""
    def one(g, e):
        ge = g.astype(jnp.float32) + e
        q, s = int8_compress(ge)
        return q, s, ge - int8_decompress(q, s)
    flat = jax.tree.map(one, grads, error)
    q = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, err


def decompress_tree(q, s):
    return jax.tree.map(int8_decompress, q, s)
