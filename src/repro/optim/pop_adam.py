"""Population-level Adam: the ``kernels/pop_adam`` Pallas kernel as an
optimizer.

The stock path applies :func:`repro.optim.adam` per member under ``vmap``,
which leaves XLA to emit one elementwise chain per pytree leaf per member.
This module exposes the alternative the kernel was written for: flatten the
population's parameters to ONE ``(N, P)`` matrix and update every member's
Adam state in a single fused pass, with the per-member learning rate (the
paper's vmapped-hyperparameter protocol) read per grid row.

Opt-in and TPU-gated: ``fused=None`` ("auto") lowers the Pallas kernel only
on TPU backends and otherwise falls back to a pure-jnp pass over the same
flattened layout — the fallback computes the exact expressions of the stock
optimizer, so numerics are identical wherever the flag is flipped
(``tests/test_experience_ppo.py`` pins this).  ``fused=True`` forces the
kernel (interpret mode off-TPU — CPU validation only).

State compatibility: ``init_fn`` produces the same ``AdamState`` structure
as ``jax.vmap(stock_init)`` (step ``(N,)``, mu/nu stacked trees), so
checkpoints, elastic resize and the gated-update bookkeeping in
``repro.core.shared`` are oblivious to which path is active.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.optim.optimizers import AdamState


def _flatten(tree):
    """Stacked tree (leaves (N, ...)) -> ((N, P) f32, rebuild fn)."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    sizes = [math.prod(l.shape[1:]) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)

    def rebuild(mat, like=None):
        outs, off = [], 0
        ref = leaves if like is None else jax.tree.leaves(like)
        for leaf, size in zip(ref, sizes):
            outs.append(mat[:, off:off + size]
                        .reshape(leaf.shape).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, outs)

    return flat, rebuild


def _use_kernel(fused) -> bool:
    if fused is None:
        return jax.default_backend() == "tpu"
    return bool(fused)


def population_adam(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8, block: int = 4096, fused=None):
    """Build ``(init_fn, apply_fn)`` over population-stacked pytrees.

        state = init_fn(stacked_params)            # leaves (N, ...)
        params, state = apply_fn(params, grads, state, lr_override=...)

    ``lr_override`` may be a scalar or an ``(N,)`` per-member vector.
    Unlike the stock pair this applies the update internally (the kernel
    fuses moment update + bias correction + apply in one pass).
    """
    kernel = _use_kernel(fused)

    def init_fn(params):
        n = jax.tree.leaves(params)[0].shape[0]
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((n,), jnp.int32),
                         mu=zeros(), nu=zeros())

    def apply_fn(params, grads, state, lr_override=None):
        n = jax.tree.leaves(params)[0].shape[0]
        lr_t = lr if lr_override is None else lr_override
        lr_vec = jnp.broadcast_to(jnp.asarray(lr_t, jnp.float32), (n,))
        step = state.step + 1

        pf, rebuild = _flatten(params)
        gf, _ = _flatten(grads)
        mf, _ = _flatten(state.mu)
        nf, _ = _flatten(state.nu)

        if kernel:
            from repro.kernels.pop_adam import pop_adam as _pa
            p = pf.shape[1]
            blk = min(block, p)
            pad = (-p) % blk
            if pad:
                z = jnp.zeros((n, pad), jnp.float32)
                pf, gf, mf, nf = (jnp.concatenate([x, z], axis=1)
                                  for x in (pf, gf, mf, nf))
            p2, m2, v2 = _pa(pf, gf, mf, nf, lr_vec, step, b1=b1, b2=b2,
                             eps=eps, block=blk,
                             interpret=jax.default_backend() != "tpu")
            if pad:
                p2, m2, v2 = (x[:, :p] for x in (p2, m2, v2))
        else:
            # the stock optimizer's expressions on the flattened layout —
            # elementwise, so bitwise-identical to vmap(stock adam)
            m2 = b1 * mf + (1 - b1) * gf
            v2 = b2 * nf + (1 - b2) * gf * gf
            stepf = step.astype(jnp.float32)[:, None]
            c1, c2 = 1 - b1 ** stepf, 1 - b2 ** stepf
            p2 = pf - lr_vec[:, None] * (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)

        new_state = AdamState(step=step, mu=rebuild(m2, state.mu),
                              nu=rebuild(v2, state.nu))
        return rebuild(p2), new_state

    return init_fn, apply_fn
