"""Population-level Adam: the ``kernels/pop_adam`` Pallas kernel as an
optimizer.

The stock path applies :func:`repro.optim.adam` per member under ``vmap``,
which leaves XLA to emit one elementwise chain per pytree leaf per member.
This module exposes the alternative the kernel was written for: flatten the
population's parameters to ONE ``(N, P)`` matrix and update every member's
Adam state in a single fused pass, with the per-member learning rate (the
paper's vmapped-hyperparameter protocol) read per grid row.

Opt-in and TPU-gated: ``fused=None`` ("auto") lowers the Pallas kernel only
on TPU backends and otherwise falls back to the stock per-member optimizer
under ``vmap`` — literally ``repro.optim.adam``, so bitwise equality with
the agents' own update path holds by construction
(``tests/test_experience_ppo.py`` and ``tests/test_lm_population.py`` pin
it).  A flattened re-derivation of the same expressions is NOT bitwise-safe
off-TPU: XLA CPU duplicates the moment mul-adds into the parameter-update
fusion and FMA-contracts them differently per program (1-2 ulp).
``fused=True`` forces the kernel (interpret mode off-TPU — CPU validation
only).

State compatibility: ``init_fn`` produces the same ``AdamState`` structure
as ``jax.vmap(stock_init)`` (step ``(N,)``, mu/nu stacked trees), so
checkpoints, elastic resize and the gated-update bookkeeping in
``repro.core.shared`` are oblivious to which path is active.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.optim.optimizers import AdamState


def _flatten(tree):
    """Stacked tree (leaves (N, ...)) -> ((N, P) f32, rebuild fn)."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    sizes = [math.prod(l.shape[1:]) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)

    def rebuild(mat, like=None):
        outs, off = [], 0
        ref = leaves if like is None else jax.tree.leaves(like)
        for leaf, size in zip(ref, sizes):
            outs.append(mat[:, off:off + size]
                        .reshape(leaf.shape).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, outs)

    return flat, rebuild


def _use_kernel(fused) -> bool:
    if fused is None:
        return jax.default_backend() == "tpu"
    return bool(fused)


def _clip_stacked(grads, max_norm):
    """Per-member global-norm clip on a stacked tree — the exact lowering of
    ``jax.vmap(clip_by_global_norm)``: per-leaf square-sums over the non-pop
    axes, python-summed in ``jax.tree.leaves`` order, one sqrt, then an
    elementwise scale of every leaf."""
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)),
                axis=tuple(range(1, x.ndim))) for x in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(
        lambda x: x * scale.reshape(scale.shape + (1,) * (x.ndim - 1)),
        grads)


def population_adam(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8, weight_decay: float = 0.0,
                    max_grad_norm=None, block: int = 4096, fused=None):
    """Build ``(init_fn, apply_fn)`` over population-stacked pytrees.

        state = init_fn(stacked_params)            # leaves (N, ...)
        params, state = apply_fn(params, grads, state, lr_override=...)

    ``lr_override`` may be a scalar or an ``(N,)`` per-member vector, as may
    ``wd_override`` (a traced per-member decoupled weight decay — the LM
    path's PBT hyper).  ``weight_decay``/``max_grad_norm`` mirror
    :func:`repro.optim.adam` so the fused path stays bitwise-equal to the
    stock optimizer under vmap.  Unlike the stock pair this applies the
    update internally (the kernel fuses moment update + bias correction +
    apply in one pass).
    """
    kernel = _use_kernel(fused)

    def init_fn(params):
        n = jax.tree.leaves(params)[0].shape[0]
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((n,), jnp.int32),
                         mu=zeros(), nu=zeros())

    def apply_fn(params, grads, state, lr_override=None, wd_override=None):
        n = jax.tree.leaves(params)[0].shape[0]
        lr_t = lr if lr_override is None else lr_override
        lr_vec = jnp.broadcast_to(jnp.asarray(lr_t, jnp.float32), (n,))
        wd = weight_decay if wd_override is None else wd_override
        decoupled = (wd_override is not None) or bool(weight_decay)

        if not kernel:
            # off-TPU fallback: stock adam under vmap, LITERALLY — reusing
            # the stock update_fn per member makes bitwise equality with
            # the agents' optax-style path true by construction.  A
            # flattened (N, P) re-derivation of the same expressions is
            # NOT bitwise-safe: XLA CPU duplicates the moment mul-adds
            # into the parameter-update fusion and FMA-contracts them
            # differently per program (1-2 ulp on this config).
            from repro.optim.optimizers import adam as _stock_adam
            from repro.optim.optimizers import apply_updates
            _, stock_upd = _stock_adam(lr, b1, b2, eps,
                                       weight_decay=weight_decay,
                                       max_grad_norm=max_grad_norm)
            wd_vec = None if not decoupled else \
                jnp.broadcast_to(jnp.asarray(wd, jnp.float32), (n,))

            def member(p, g, m, v, s, lr_i, wd_i=None):
                st = AdamState(step=s, mu=m, nu=v)
                u, st2 = stock_upd(g, st, p, lr_override=lr_i,
                                   wd_override=wd_i)
                return apply_updates(p, u), st2

            if wd_vec is None:
                p2, new_state = jax.vmap(member)(
                    params, grads, state.mu, state.nu, state.step, lr_vec)
            else:
                p2, new_state = jax.vmap(member)(
                    params, grads, state.mu, state.nu, state.step, lr_vec,
                    wd_vec)
            return p2, new_state

        if max_grad_norm is not None:
            grads = _clip_stacked(grads, max_grad_norm)
        step = state.step + 1

        pf, rebuild = _flatten(params)
        gf, _ = _flatten(grads)
        mf, _ = _flatten(state.mu)
        nf, _ = _flatten(state.nu)

        from repro.kernels.pop_adam import pop_adam as _pa
        p = pf.shape[1]
        blk = min(block, p)
        pad = (-p) % blk
        if pad:
            z = jnp.zeros((n, pad), jnp.float32)
            pf, gf, mf, nf = (jnp.concatenate([x, z], axis=1)
                              for x in (pf, gf, mf, nf))
        p2, m2, v2 = _pa(pf, gf, mf, nf, lr_vec, step, b1=b1, b2=b2,
                         eps=eps, block=blk,
                         interpret=jax.default_backend() != "tpu")
        if pad:
            p2, m2, v2 = (x[:, :p] for x in (p2, m2, v2))
        if decoupled:
            # the kernel has no decay term; post-apply it (kernel mode
            # is numerics-checked against the fallback, not bitwise)
            wd_vec = jnp.broadcast_to(jnp.asarray(wd, jnp.float32), (n,))
            p2 = p2 - (lr_vec * wd_vec)[:, None] * pf[:, :p2.shape[1]]

        new_state = AdamState(step=step, mu=rebuild(m2, state.mu),
                              nu=rebuild(v2, state.nu))
        return rebuild(p2), new_state

    return init_fn, apply_fn
