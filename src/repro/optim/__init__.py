from repro.optim.optimizers import (  # noqa: F401
    adam, adamw, sgd, clip_by_global_norm, global_norm,
    cosine_schedule, warmup_cosine, dynamic_warmup_cosine, apply_updates,
)
from repro.optim.pop_adam import population_adam  # noqa: F401
from repro.optim.compress import int8_compress, int8_decompress  # noqa: F401
