"""Pytree-functional optimizers (optax is unavailable — built from scratch).

An optimizer is a pair ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params, lr=None)
``lr`` may be passed dynamically at update time — this is what lets PBT treat
the learning rate as a *vmapped per-member hyperparameter* (the paper's §5.1):
the same compiled update step serves every member with its own lr.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Any

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def adam(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         max_grad_norm: float | None = None):
    """Adam/AdamW. ``update_fn(grads, state, params, lr=...)`` overrides lr."""

    def init_fn(params):
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def update_fn(grads, state, params=None, lr_override=None,
                  wd_override=None):
        lr_t = lr if lr_override is None else lr_override
        wd = weight_decay if wd_override is None else wd_override
        decoupled = (wd_override is not None) or bool(weight_decay)
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, n, p):
            u = -(lr_t * (m / c1) / (jnp.sqrt(n / c2) + eps))
            if decoupled:
                u = u - lr_t * wd * p.astype(jnp.float32)
            return u
        updates = jax.tree.map(upd, mu, nu,
                               params if decoupled else jax.tree.map(lambda m: m, mu))
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return init_fn, update_fn


def adamw(lr: float = 3e-4, weight_decay: float = 0.1, **kw):
    return adam(lr=lr, weight_decay=weight_decay, **kw)


def sgd(lr: float = 1e-2, momentum: float = 0.0):
    def init_fn(params):
        if momentum:
            return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return ()

    def update_fn(grads, state, params=None, lr_override=None):
        lr_t = lr if lr_override is None else lr_override
        if momentum:
            state = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32),
                                 state, grads)
            return jax.tree.map(lambda v: -lr_t * v, state), state
        return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state

    return init_fn, update_fn


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr_at(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        return base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return lr_at


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_frac)
    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return lr_at


def dynamic_warmup_cosine(base_lr: float, total_steps: int,
                          final_frac: float = 0.1):
    """:func:`warmup_cosine` with the warmup length as a *traced* fraction
    of ``total_steps`` — the form PBT needs to treat warmup as a perturbable
    per-member hyperparameter.  ``lr_at(step, warmup_frac)`` is elementwise,
    so vmapping it over per-member ``(step, warmup_frac)`` scalars and
    evaluating it on ``(N,)`` vectors produce the same lowering."""
    def lr_at(step, warmup_frac):
        step = step.astype(jnp.float32)
        warm_steps = jnp.maximum(
            jnp.asarray(warmup_frac, jnp.float32) * total_steps, 1.0)
        span = jnp.maximum(total_steps - warm_steps, 1.0)
        warm = base_lr * step / warm_steps
        t = jnp.minimum(step - warm_steps, span) / span
        cos = base_lr * (final_frac +
                         (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warm_steps, warm, cos)
    return lr_at
