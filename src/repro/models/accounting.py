"""Parameter / FLOP accounting for the roofline report.

MODEL_FLOPS follows the task spec: 6*N*D for training (N = active params,
D = tokens), 2*N*D for inference passes.  Attention score FLOPs
(O(S^2) terms) are intentionally excluded — the ratio MODEL_FLOPS/HLO_FLOPS
in EXPERIMENTS.md therefore *also* surfaces attention/remat/dispatch
overheads, which is what we iterate on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec
from repro.models import lm as lm_mod


def _leaf_sizes_with_paths(cfg: LMConfig):
    params = jax.eval_shape(lambda k: lm_mod.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in flat:
        p = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        out.append((p, int(leaf.size)))
    return out


def param_count(cfg: LMConfig) -> int:
    return sum(s for _, s in _leaf_sizes_with_paths(cfg))


def active_param_count(cfg: LMConfig) -> int:
    """Experts scaled by top_k/E; the zamba shared block counted once per
    invocation (it runs num_layers/shared_attn_every times)."""
    total = 0.0
    moe_scale = (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 1.0
    shared_mult = 1.0
    if cfg.shared_attn_every:
        n_inv = -(-cfg.num_layers // cfg.shared_attn_every)  # ceil
        shared_mult = float(n_inv)
    for path, size in _leaf_sizes_with_paths(cfg):
        if "experts" in path:
            total += size * moe_scale
        elif path.startswith("shared_attn"):
            total += size * shared_mult
        elif path.startswith("embed") and not cfg.tie_embeddings:
            # embedding lookup is a gather, not a matmul; exclude from the
            # 6ND model (tied heads keep it — it is the output matmul then)
            continue
        else:
            total += size
    return int(total)


def model_flops(cfg: LMConfig, shape: ShapeSpec) -> float:
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
