"""Sharding rules: parameter PartitionSpecs + activation constraints.

Conventions (mesh axes: optional "pod", then "data", "model"):
  * TP  — the "wide" dim of every projection is sharded over ``model``
          (attention heads, ffn columns, experts, vocab).
  * FSDP/ZeRO — the other matmul dim is sharded over ("pod","data"); the
          optimizer state inherits the same specs, giving ZeRO-3 layout.
  * stacked layer axes (from scan-over-layers) are never sharded.
  * activations: batch over ("pod","data"), sequence over "model"
          (sequence parallelism) for full-sequence passes; decode keeps the
          KV cache sharded (batch over data, sequence over model).

These are *requests*: `constrain`/`spec_for` drop axes that do not divide the
corresponding dim, so small smoke configs and batch-1 decode fall back to
replication instead of erroring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat

# parameter-name → (spec for trailing dims) tables.  Leading stacked layer
# axes are padded with None automatically.  "F" = fsdp axes, "M" = model.
_UP = ("F", "M")      # (d_in, d_out_wide)
_DOWN = ("M", "F")    # (d_in_wide, d_out)
_RULES = {
    # attention
    "wq": _UP, "wk": _UP, "wv": _UP, "wo": _DOWN,
    # mla
    "w_dkv": _UP, "w_kr": ("F", None), "w_ukv": (None, "M"),
    # glu mlp
    "w_gate": _UP, "w_up": _UP, "w_down": _DOWN,
    # moe (experts have a leading E dim sharded over model = EP)
    "router": ("F", None),
    "experts.w_gate": ("M", "F", None), "experts.w_up": ("M", "F", None),
    "experts.w_down": ("M", None, "F"),
    # rwkv6
    "wr": _UP, "wg": _UP,
    "mix_w1": ("F", None), "mix_w2": (None, None, None),
    "decay_w1": ("F", None), "decay_w2": (None, None),
    # mamba2
    "in_proj": _UP, "out_proj": _DOWN, "conv": (None, "M"),
    # embedding / head
    "embedding": ("M", "F"), "lm_head": ("F", "M"),
}


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def fsdp_axes(mesh):
    names = _axes(mesh)
    return tuple(a for a in ("pod", "data") if a in names) or None


_POPULATION_MODE = False


class population_mode:
    """Context: the ('pod','data') axes hold population members, so every
    'F' (FSDP/data-parallel) request inside the model resolves to None —
    member-internal sharding is TP-only (the population IS the data axis)."""

    def __enter__(self):
        global _POPULATION_MODE
        self._prev = _POPULATION_MODE
        _POPULATION_MODE = True

    def __exit__(self, *exc):
        global _POPULATION_MODE
        _POPULATION_MODE = self._prev


def _resolve(sym, mesh):
    if sym == "F":
        return None if _POPULATION_MODE else fsdp_axes(mesh)
    if sym == "M":
        return "model" if "model" in _axes(mesh) else None
    return sym


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def spec_for(path: str, shape, mesh) -> P:
    """Find the rule for a param path like 'segments.moe.attn.wq.w'."""
    parts = [p for p in path.split(".") if p not in ("w",)]
    rule = None
    for span in (2, 1):           # longer (more specific) matches win
        for i in range(len(parts) - span + 1):
            key = ".".join(parts[i:i + span])
            if key in _RULES:
                rule = _RULES[key]
        if rule is not None:
            break
    if rule is None:
        return P()
    dims = [_resolve(s, mesh) for s in rule]
    # left-pad with None for stacked layer axes
    dims = [None] * (len(shape) - len(dims)) + dims
    # drop any axis that does not divide its dim
    out = []
    for d, ax in zip(shape, dims):
        out.append(ax if ax is not None and d % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return ".".join(out)


def param_specs(params, mesh):
    """PartitionSpec pytree mirroring ``params`` (rules above)."""
    def one(path, leaf):
        return spec_for(_path_str(path), leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activation constraints (mesh-context aware, divisibility-safe)
# ---------------------------------------------------------------------------


def constrain(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context and drops
    non-dividing axes. ``spec`` entries may be 'F'/'M' symbols."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    dims = []
    for d, sym in zip(x.shape, spec):
        ax = _resolve(sym, mesh)
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax if a in mesh.axis_names) or None
        elif ax is not None and ax not in mesh.axis_names:
            ax = None
        dims.append(ax if ax is not None and d % _axis_size(mesh, ax) == 0 else None)
    dims += [None] * (len(x.shape) - len(dims))
    return jax.lax.with_sharding_constraint(x, P(*dims))


def constrain_tree(params):
    """Constrain every leaf of a (layer-local) param subtree to its rule spec.

    Applied inside scan bodies: pinning the per-layer parameter sharding also
    pins the COTANGENT sharding in the backward pass, which turns XLA's
    per-layer full-tensor gradient all-reduces into reduce-scatters (§Perf
    iteration 1 — a 2-4x collective-bytes reduction on MoE/dense train).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return params

    def one(path, leaf):
        spec = spec_for(_path_str(path), leaf.shape, mesh)
        if all(s is None for s in spec):
            return leaf
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(shape, mesh, *, leading_batch: bool = True):
    """NamedSharding spec for a host batch array: batch over ('pod','data')."""
    f = fsdp_axes(mesh)
    if f is None or shape[0] % _axis_size(mesh, f) != 0:
        f = None
    return P(f, *([None] * (len(shape) - 1)))
