"""Unified decoder LM covering all assigned architectures.

One config-driven model family:
  * dense / MoE / MLA attention transformers (qwen2/3, gemma, pixtral,
    musicgen, qwen3-moe, deepseek-v2-lite)
  * RWKV6 (attention-free)
  * Mamba2 (+ Zamba2 shared-attention hybrid)

Structure is organised as *segments* of homogeneous blocks; each segment is a
``jax.lax.scan`` over stacked layer parameters (keeps the HLO small enough
that the 512-device dry-run compiles for 48-81 layer models).  Decode state
(KV caches / SSM states) is threaded through the same scans as stacked xs/ys.

Public API:
    init_params(key, cfg)
    forward(params, cfg, batch, state=None, cache_index=None)
    make_train_step(cfg, tcfg) / make_serve_step(cfg)
    init_decode_state(cfg, batch, max_len)
    input_specs(cfg, shape)  -> ShapeDtypeStruct stand-ins (no allocation)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec, TrainConfig
from repro.kernels import ops as kernel_ops
from repro.models.sharding import constrain, constrain_tree
from repro.nn.attention import (gqa_apply, gqa_init, mla_apply, mla_init)
from repro.nn.basic import (cast, embedding_init, glu_mlp_apply, glu_mlp_init,
                            layernorm_apply, layernorm_init, lecun_normal,
                            rmsnorm_apply, rmsnorm_init)
from repro.nn.mamba2 import mamba2_block_apply, mamba2_block_init
from repro.nn.moe import moe_apply, moe_init
from repro.nn.rwkv6 import (channel_mix_apply, rwkv6_block_init,
                            time_mix_apply)
from repro.optim import (adam, apply_updates, dynamic_warmup_cosine,
                         population_adam, warmup_cosine)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    name: str
    kind: str            # attn | rwkv | mamba
    count: int           # scan length
    inner: int = 1       # mamba layers per scanned super-block
    moe: bool = False
    shared_attn: bool = False


def layout(cfg: LMConfig) -> list[Segment]:
    if cfg.block_type == "attention":
        nd = cfg.num_layers if cfg.moe is None else cfg.moe.first_dense_layers
        nm = 0 if cfg.moe is None else cfg.num_layers - nd
        segs = []
        if nd:
            segs.append(Segment("dense", "attn", nd))
        if nm:
            segs.append(Segment("moe", "attn", nm, moe=True))
        return segs
    if cfg.block_type == "rwkv6":
        return [Segment("rwkv", "rwkv", cfg.num_layers)]
    if cfg.block_type == "mamba2":
        if cfg.shared_attn_every:
            inner = cfg.shared_attn_every
            n_super, rem = divmod(cfg.num_layers, inner)
            segs = [Segment("mamba_main", "mamba", n_super, inner=inner,
                            shared_attn=True)]
            if rem:
                segs.append(Segment("mamba_tail", "mamba", 1, inner=rem,
                                    shared_attn=True))
            return segs
        return [Segment("mamba", "mamba", cfg.num_layers)]
    raise ValueError(cfg.block_type)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _attn_block_init(key, cfg: LMConfig, moe_layer: bool):
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"attn_norm": rmsnorm_init(cfg.d_model),
                         "mlp_norm": rmsnorm_init(cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = mla_init(k1, d_model=cfg.d_model, num_heads=cfg.num_heads,
                             kv_lora_rank=cfg.mla.kv_lora_rank,
                             qk_nope_dim=cfg.mla.qk_nope_dim,
                             qk_rope_dim=cfg.mla.qk_rope_dim,
                             v_dim=cfg.mla.v_dim)
    else:
        p["attn"] = gqa_init(k1, d_model=cfg.d_model, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                             qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    if moe_layer:
        m = cfg.moe
        p["mlp"] = moe_init(k2, d_model=cfg.d_model, d_expert=m.d_expert,
                            num_experts=m.num_experts, num_shared=m.num_shared)
    else:
        p["mlp"] = glu_mlp_init(k2, cfg.d_model, cfg.d_ff)
    return p


def _attn_block_apply(p, cfg: LMConfig, h, positions, cache, cache_index,
                      moe_layer: bool, use_kernels=False):
    p = constrain_tree(p)  # pins param+cotangent shardings inside the scan
    y = rmsnorm_apply(p["attn_norm"], h)
    if cfg.mla is not None:
        m = cfg.mla
        y, new_cache = mla_apply(
            p["attn"], y, positions, num_heads=cfg.num_heads,
            kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
            qk_rope_dim=m.qk_rope_dim, v_dim=m.v_dim,
            rope_theta=cfg.rope_theta, cache=cache, cache_index=cache_index)
    else:
        y, new_cache = gqa_apply(
            p["attn"], y, positions, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, cache=cache, cache_index=cache_index,
            attn_fn=kernel_ops.attention_fn(use_kernels))
    h = constrain(h + y, "F", "M", None)
    y = rmsnorm_apply(p["mlp_norm"], h)
    if moe_layer:
        m = cfg.moe
        y, aux = moe_apply(p["mlp"], y, num_experts=m.num_experts, top_k=m.top_k,
                           capacity_factor=m.capacity_factor,
                           group_size=m.group_size, activation=cfg.activation)
    else:
        y, aux = glu_mlp_apply(p["mlp"], y, activation=cfg.activation), \
            jnp.zeros((), jnp.float32)
    h = constrain(h + y, "F", "M", None)
    return h, new_cache, aux


def _rwkv_block_init(key, cfg: LMConfig):
    p = rwkv6_block_init(key, d_model=cfg.d_model, d_ff=cfg.d_ff,
                         head_dim=cfg.ssm_head_dim)
    p["ln1"] = layernorm_init(cfg.d_model)
    p["ln2"] = layernorm_init(cfg.d_model)
    return p


def _rwkv_block_apply(p, cfg: LMConfig, h, state, use_kernels=False):
    """state: {"wkv","tm_x","cm_x"} (decode) or None (fresh zeros)."""
    p = constrain_tree(p)
    b = h.shape[0]
    nh = cfg.d_model // cfg.ssm_head_dim
    if state is None:
        state = {
            "wkv": jnp.zeros((b, nh, cfg.ssm_head_dim, cfg.ssm_head_dim),
                             jnp.float32),
            "tm_x": jnp.zeros((b, 1, cfg.d_model), h.dtype),
            "cm_x": jnp.zeros((b, 1, cfg.d_model), h.dtype),
        }
    x = layernorm_apply(p["ln1"], h)
    y, wkv, tm_x = time_mix_apply(p["time_mix"], x, state["tm_x"].astype(h.dtype),
                                  state["wkv"], head_dim=cfg.ssm_head_dim,
                                  use_chunked=cfg.use_chunked,
                                  chunk=min(cfg.ssm_chunk, 64),
                                  compute_dtype=jnp.dtype(cfg.ssm_compute_dtype),
                                  use_kernels=use_kernels)
    h = constrain(h + y, "F", "M", None)
    x = layernorm_apply(p["ln2"], h)
    y, cm_x = channel_mix_apply(p["channel_mix"], x, state["cm_x"].astype(h.dtype))
    h = constrain(h + y, "F", "M", None)
    new_state = {"wkv": wkv, "tm_x": tm_x.astype(state["tm_x"].dtype),
                 "cm_x": cm_x.astype(state["cm_x"].dtype)}
    return h, new_state


def _mamba_layer_init(key, cfg: LMConfig):
    return {"norm": rmsnorm_init(cfg.d_model),
            "mamba": mamba2_block_init(key, d_model=cfg.d_model,
                                       d_state=cfg.ssm_state,
                                       head_dim=cfg.ssm_head_dim)}


def _mamba_layer_apply(p, cfg: LMConfig, h, state, use_kernels=False):
    p = constrain_tree(p)
    b = h.shape[0]
    if state is None:
        d_inner = 2 * cfg.d_model
        nh = d_inner // cfg.ssm_head_dim
        state = {"ssm": jnp.zeros((b, nh, cfg.ssm_head_dim, cfg.ssm_state),
                                  jnp.float32),
                 "conv": jnp.zeros((b, 3, d_inner + 2 * cfg.ssm_state), h.dtype)}
    y, new_state = mamba2_block_apply(
        p["mamba"], rmsnorm_apply(p["norm"], h), state,
        d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
        use_chunked=cfg.use_chunked, chunk=cfg.ssm_chunk,
        compute_dtype=jnp.dtype(cfg.ssm_compute_dtype),
        use_kernels=use_kernels)
    return constrain(h + y, "F", "M", None), new_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg: LMConfig):
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"segments": {}}
    if cfg.frontend != "audio_frames":
        params["embed"] = embedding_init(keys[0], cfg.vocab_size, cfg.d_model)
    for i, seg in enumerate(layout(cfg)):
        kseg = jax.random.fold_in(keys[1], i)
        if seg.kind == "attn":
            fn = partial(_attn_block_init, cfg=cfg, moe_layer=seg.moe)
            params["segments"][seg.name] = _stacked_init(kseg, seg.count, fn)
        elif seg.kind == "rwkv":
            fn = partial(_rwkv_block_init, cfg=cfg)
            params["segments"][seg.name] = _stacked_init(kseg, seg.count, fn)
        else:  # mamba / zamba super-blocks
            fn = partial(_mamba_layer_init, cfg=cfg)
            if seg.inner > 1 or seg.shared_attn:
                inner_fn = lambda k: _stacked_init(k, seg.inner, fn)
                params["segments"][seg.name] = _stacked_init(kseg, seg.count,
                                                             inner_fn)
            else:
                params["segments"][seg.name] = _stacked_init(kseg, seg.count, fn)
    if cfg.shared_attn_every:
        params["shared_attn"] = _attn_block_init(keys[2], cfg, moe_layer=False)
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": lecun_normal(keys[3],
                                               (cfg.d_model, cfg.vocab_size))}
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _segment_forward(seg: Segment, seg_params, shared_p, cfg: LMConfig, h,
                     positions, seg_state, cache_index, train: bool,
                     use_kernels=False):
    collect_state = seg_state is not None

    def body(h, xs):
        layer_p, layer_st = xs
        aux = jnp.zeros((), jnp.float32)
        if seg.kind == "attn":
            cache = layer_st["kv"] if collect_state else None
            h, new_cache, aux = _attn_block_apply(
                layer_p, cfg, h, positions, cache, cache_index, seg.moe,
                use_kernels)
            new_st = {"kv": new_cache} if collect_state else None
        elif seg.kind == "rwkv":
            h, new_st = _rwkv_block_apply(layer_p, cfg, h,
                                          layer_st if collect_state else None,
                                          use_kernels)
            new_st = new_st if collect_state else None
        else:  # mamba (possibly zamba super-block with shared attention)
            if seg.shared_attn:
                cache = layer_st["attn"]["kv"] if collect_state else None
                h, new_cache, _ = _attn_block_apply(
                    shared_p, cfg, h, positions, cache, cache_index, False,
                    use_kernels)
                new_mamba = []
                for i in range(seg.inner):
                    pi = jax.tree.map(lambda a: a[i], layer_p)
                    sti = (jax.tree.map(lambda a: a[i], layer_st["mamba"])
                           if collect_state else None)
                    h, st_i = _mamba_layer_apply(pi, cfg, h, sti, use_kernels)
                    new_mamba.append(st_i)
                if collect_state:
                    new_st = {"attn": {"kv": new_cache},
                              "mamba": jax.tree.map(
                                  lambda *xs: jnp.stack(xs), *new_mamba)}
                else:
                    new_st = None
            else:
                h, new_st = _mamba_layer_apply(layer_p, cfg, h,
                                               layer_st if collect_state else None,
                                               use_kernels)
                new_st = new_st if collect_state else None
        return h, (new_st, aux)

    if cfg.remat and train:
        body = jax.checkpoint(body)
    h, (new_states, auxs) = jax.lax.scan(body, h, (seg_params, seg_state))
    return h, new_states, jnp.sum(auxs)


def forward(params, cfg: LMConfig, batch, state=None, cache_index=None,
            train: bool = False, return_hidden: bool = False):
    """batch: {"tokens": (B,S) int32, ["embeds"], ["patch_embeds"]}.

    Returns (logits_or_hidden, new_state, aux_loss)."""
    dtype = jnp.dtype(cfg.dtype)
    cparams = cast(params, dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape

    if cfg.frontend == "audio_frames":
        h = batch["embeds"].astype(dtype)
    else:
        h = cparams["embed"]["embedding"][tokens]
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            npatch = batch["patch_embeds"].shape[1]
            if cache_index is None:  # full-sequence pass: splice patch prefix
                h = jnp.concatenate(
                    [batch["patch_embeds"].astype(dtype), h[:, npatch:]], axis=1)
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, dtype)

    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    else:
        positions = cache_index + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))

    h = constrain(h, "F", "M", None)
    # kernels/ops dispatch: "auto" (None) means kernels only on TPU and only
    # for non-differentiated forwards — the Pallas kernels carry no custom
    # VJPs, so training autodiff always takes the (bitwise-pinned) jnp path.
    uk = cfg.use_kernels
    if uk is None:
        uk = False if train else (True if cfg.use_flash else None)
    aux_total = jnp.zeros((), jnp.float32)
    new_state = {} if state is not None else None
    for seg in layout(cfg):
        seg_state = state[seg.name] if state is not None else None
        shared_p = cparams.get("shared_attn")
        h, seg_new, aux = _segment_forward(
            seg, cparams["segments"][seg.name], shared_p, cfg, h, positions,
            seg_state, cache_index, train, uk)
        if state is not None:
            new_state[seg.name] = seg_new
        aux_total = aux_total + aux

    h = rmsnorm_apply(params["final_norm"], h)
    if return_hidden:
        return h, new_state, aux_total
    logits = h @ _head_weight(cparams, cfg)
    return logits, new_state, aux_total


def _head_weight(cparams, cfg: LMConfig):
    if cfg.tie_embeddings:
        # vocab-shard the tied head even when the embedding table itself is
        # replicated (population mode): keeps the logits vocab-parallel.
        return constrain(cparams["embed"]["embedding"].T, None, "M")
    return cparams["lm_head"]["w"]


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------


def _token_ce(logits, labels, mask):
    logits = constrain(logits.astype(jnp.float32), "F", None, "M")
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via a fused masked reduction instead of take_along_axis:
    # the gather on the vocab-sharded axis forced XLA to all-gather the
    # full fp32 logits; the where+sum keeps everything vocab-local and
    # all-reduces only the (B,S) partials (§Perf CE iteration).
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    ce = (logz - gold) * mask
    return jnp.sum(ce), jnp.sum(mask)


def lm_loss(params, cfg: LMConfig, batch, train: bool = True):
    hidden, _, aux = forward(params, cfg, batch, train=train,
                             return_hidden=True)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    if cfg.frontend == "vision_patches" and cfg.num_frontend_positions:
        mask = mask.at[:, :cfg.num_frontend_positions].set(0.0)
    w = _head_weight(cast(params, jnp.dtype(cfg.dtype)), cfg)

    if cfg.logits_chunk and hidden.shape[1] % cfg.logits_chunk == 0:
        nc = hidden.shape[1] // cfg.logits_chunk
        def body(carry, xs):
            h_c, l_c, m_c = xs
            ce, n = _token_ce(h_c @ w, l_c, m_c)
            return (carry[0] + ce, carry[1] + n), None
        reshape = lambda x: jnp.moveaxis(
            x.reshape(x.shape[0], nc, cfg.logits_chunk, *x.shape[2:]), 1, 0)
        (ce, n), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())),
            (reshape(hidden), reshape(labels), reshape(mask)))
    else:
        ce, n = _token_ce(hidden @ w, labels, mask)
    loss = ce / jnp.maximum(n, 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux / max(
            cfg.num_layers - cfg.moe.first_dense_layers, 1)
    return loss, {"ce": ce / jnp.maximum(n, 1.0), "aux": aux}


def _make_grads_fn(cfg: LMConfig, tcfg: TrainConfig):
    """Per-member gradient pass shared by the stock train step (scalar, run
    under vmap by the vectorized backend) and the fused population update
    (vmapped here) — ONE definition so both paths trace the same HLO."""

    def grads_of(params, batch):
        if tcfg.grad_accum > 1:
            # microbatching: split the batch over the leading axis and
            # accumulate grads in fp32 via a scan (memory ~1/grad_accum)
            k = tcfg.grad_accum
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(
                    lambda p: lm_loss(p, cfg, mb), has_aux=True)(params)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / k, acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        return grads, loss, metrics

    return grads_of


def _make_lr_fn(tcfg: TrainConfig):
    """``lr_at(step, lr_scale, warmup_frac)``: the static warmup-cosine
    schedule when ``warmup_frac`` is None (legacy numerics), the dynamic
    schedule when it is a traced PBT hyper.  Elementwise, so evaluating it
    on ``(N,)`` vectors matches the scalar form under vmap bitwise."""
    static = warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    dynamic = dynamic_warmup_cosine(tcfg.lr, tcfg.total_steps)

    def lr_at(step, lr_scale=None, warmup_frac=None):
        lr = static(step) if warmup_frac is None else dynamic(step, warmup_frac)
        if lr_scale is not None:
            lr = lr * lr_scale
        return lr

    return lr_at


def make_train_step(cfg: LMConfig, tcfg: TrainConfig):
    opt_init, opt_update = adam(tcfg.lr, weight_decay=tcfg.weight_decay,
                                max_grad_norm=tcfg.max_grad_norm)
    grads_of = _make_grads_fn(cfg, tcfg)
    lr_at = _make_lr_fn(tcfg)

    def train_step(params, opt_state, batch, step, lr_scale=None,
                   weight_decay=None, warmup_frac=None):
        grads, loss, metrics = grads_of(params, batch)
        lr = lr_at(step, lr_scale, warmup_frac)
        updates, opt_state = opt_update(grads, opt_state, params,
                                        lr_override=lr,
                                        wd_override=weight_decay)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, step=step)
        return params, opt_state, metrics

    return opt_init, train_step


def make_population_update(cfg: LMConfig, tcfg: TrainConfig, *, fused=None):
    """Population-level LM update with the optimizer hoisted into
    :func:`repro.optim.population_adam` (PR 8's fused_adam hoist, LM
    edition): per-member gradients under vmap, ONE flattened ``(N, P)``
    Adam application for the whole population.  Signature matches the
    backend registry's fused protocol::

        update(pop_state, batch, hypers) -> (pop_state, metrics)

    ``hypers`` may carry per-member ``lr_scale`` / ``weight_decay`` /
    ``warmup_frac`` vectors; absent keys fall back to the static
    ``TrainConfig`` values — in both cases the result is bitwise-equal to
    the stock ``train_step`` under vmap (``tests/test_lm_population.py``
    pins this on the tiny config)."""
    _, pop_apply = population_adam(
        tcfg.lr, weight_decay=tcfg.weight_decay,
        max_grad_norm=tcfg.max_grad_norm, fused=fused)
    grads_of = _make_grads_fn(cfg, tcfg)
    lr_at = _make_lr_fn(tcfg)

    def pop_update(state, batch, hypers=None):
        from repro.pop.agent import LMState  # lazy: pop.agent imports lm
        h = hypers if hypers else {}
        grads, loss, metrics = jax.vmap(grads_of)(state.params, batch)
        lr = lr_at(state.step, h.get("lr_scale"), h.get("warmup_frac"))
        params, opt_state = pop_apply(state.params, grads, state.opt_state,
                                      lr_override=lr,
                                      wd_override=h.get("weight_decay"))
        metrics = dict(metrics, loss=loss, step=state.step)
        return LMState(params=params, opt_state=opt_state,
                       step=state.step + 1), metrics

    return pop_update


def make_serve_step(cfg: LMConfig):
    def serve_step(params, batch, state, cache_index):
        logits, new_state, _ = forward(params, cfg, batch, state=state,
                                       cache_index=cache_index)
        return logits, new_state
    return serve_step


# ---------------------------------------------------------------------------
# decode state + input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _seg_state_shape(seg: Segment, cfg: LMConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    if seg.kind == "attn" or seg.shared_attn:
        if cfg.mla is not None and seg.kind == "attn":
            attn = {"c_kv": ((batch, max_len, cfg.mla.kv_lora_rank), dtype),
                    "k_rope": ((batch, max_len, cfg.mla.qk_rope_dim), dtype)}
        else:
            attn = {"k": ((batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
                    "v": ((batch, max_len, cfg.num_kv_heads, cfg.hd), dtype)}
    if seg.kind == "attn":
        return {"kv": attn}
    if seg.kind == "rwkv":
        nh = cfg.d_model // cfg.ssm_head_dim
        return {"wkv": ((batch, nh, cfg.ssm_head_dim, cfg.ssm_head_dim),
                        jnp.float32),
                "tm_x": ((batch, 1, cfg.d_model), dtype),
                "cm_x": ((batch, 1, cfg.d_model), dtype)}
    d_inner = 2 * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    mamba = {"ssm": ((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
             "conv": ((batch, 3, d_inner + 2 * cfg.ssm_state), dtype)}
    if seg.shared_attn:
        mamba = {"mamba": jax.tree.map(
            lambda t: ((seg.inner,) + t[0], t[1]), mamba,
            is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)),
            "attn": {"kv": attn}}
    return mamba


def _materialize(tree, make):
    is_shape = lambda x: (isinstance(x, tuple) and len(x) == 2
                          and isinstance(x[0], tuple))
    return jax.tree.map(lambda t: make(t[0], t[1]), tree, is_leaf=is_shape)


def decode_state_shapes(cfg: LMConfig, batch: int, max_len: int):
    out = {}
    for seg in layout(cfg):
        shapes = _seg_state_shape(seg, cfg, batch, max_len)
        out[seg.name] = _materialize(
            shapes, lambda s, d: ((seg.count,) + s, d))
    return out


def init_decode_state(cfg: LMConfig, batch: int, max_len: int):
    shapes = decode_state_shapes(cfg, batch, max_len)
    is_shape = lambda x: (isinstance(x, tuple) and len(x) == 2
                          and isinstance(x[0], tuple))
    return jax.tree.map(lambda t: jnp.zeros(t[0], t[1]), shapes,
                        is_leaf=is_shape)


def decode_state_specs(cfg: LMConfig, batch: int, max_len: int):
    shapes = decode_state_shapes(cfg, batch, max_len)
    is_shape = lambda x: (isinstance(x, tuple) and len(x) == 2
                          and isinstance(x[0], tuple))
    return jax.tree.map(lambda t: jax.ShapeDtypeStruct(t[0], t[1]), shapes,
                        is_leaf=is_shape)


def input_specs(cfg: LMConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        s = shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.ShapeDtypeStruct(
            (b, batch["tokens"].shape[1], cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_patches" and shape.kind != "decode":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frontend_positions, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch
