"""``Evaluator`` — vmapped deterministic evaluation episodes.

PBT's exploit/explore, CEM's elite refit and DvD's selection all consume a
per-member scalar fitness; the paper gets it cheaply by running evaluation
episodes on device with the deterministic policy (no exploration noise,
greedy argmax for DQN).  One call plays ``num_envs`` fresh episodes per
member — every env stops accumulating at its FIRST terminal so auto-reset
never leaks a second episode into the score — and returns the mean
first-episode return per member, shape (N,).

The policy itself is NOT this module's: the Evaluator is env-stepping
composed with :class:`repro.serve.PolicyForward` — the same deterministic
forward the serving engine batches external traffic through — so the
fitness that promotes a member into the serving ensemble describes
bit-exactly the policy that serves (``tests/test_serve.py`` pins the
equality on all four RL algorithms).

The whole thing is one jitted ``vmap`` over members; with a fixed key it is
bitwise deterministic, which ``tests/test_rollout.py`` asserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.core import Env
from repro.rollout.vecenv import VecEnv
from repro.serve.forward import PolicyForward


class Evaluator:
    def __init__(self, env: Env, policy_fn=None, *, num_envs: int = 4,
                 num_steps: int | None = None, forward=None):
        if (policy_fn is None) == (forward is None):
            raise ValueError("Evaluator takes exactly one of policy_fn "
                             "(wrapped into a PolicyForward) or forward=")
        self.forward = forward if forward is not None \
            else PolicyForward(policy_fn)
        self.policy_fn = self.forward.policy_fn
        self.venv = VecEnv(env, num_envs)
        self.num_steps = num_steps or env.spec.episode_length
        self._evaluate = jax.jit(jax.vmap(self._member_eval))
        # size-1 populations skip the member vmap (XLA CPU compiles
        # size-1-vmapped scans ~4x slower; see Collector.collect)
        self._evaluate1 = jax.jit(self._member_eval)

    def _member_eval(self, actor, key):
        vs = self.venv.reset(key)
        ret0 = jnp.zeros((self.venv.num_envs,))
        alive0 = jnp.ones((self.venv.num_envs,))

        def body(carry, _):
            vs, ret, alive = carry
            actions = self.forward.member(actor, vs.obs)
            vs, trans = self.venv.step(vs, actions)
            ret = ret + trans["reward"] * alive
            # episode END (termination or truncation), not the transition's
            # bootstrap mask: the running length resets to 0 on either
            ended = (vs.episode_length == 0).astype(jnp.float32)
            alive = alive * (1.0 - ended)
            return (vs, ret, alive), None

        (_, ret, _), _ = jax.lax.scan(body, (vs, ret0, alive0), None,
                                      length=self.num_steps)
        return ret.mean()

    def evaluate(self, actors, key):
        """Per-member fitness, shape (N,): mean deterministic first-episode
        return over ``num_envs`` fresh evaluation episodes."""
        n = jax.tree.leaves(actors)[0].shape[0]
        keys = jax.random.split(key, n)
        if n == 1:
            one = self._evaluate1(jax.tree.map(lambda x: x[0], actors),
                                  keys[0])
            return one[None]
        return self._evaluate(actors, keys)
