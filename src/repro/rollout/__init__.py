# On-device acting engine: batched envs, population-vectorized collection,
# deterministic evaluation, and the fused train iteration — off-policy
# (collect->insert->sample->update) or on-policy (collect->GAE->epoch/
# minibatch scan), dispatched on the agent's experience kind (the acting-
# side half of the paper, alongside repro.pop and repro.data.experience).
from repro.rollout.vecenv import (  # noqa: F401
    VecEnv, VecEnvState, episode_stats, reset_stats,
)
from repro.rollout.collector import (  # noqa: F401
    Collector, exploration_policy, default_exploration, split_actions,
)
from repro.rollout.evaluator import Evaluator  # noqa: F401
from repro.rollout.engine import RolloutEngine, transition_spec  # noqa: F401
from repro.rollout.overlap import OverlapEngine  # noqa: F401
