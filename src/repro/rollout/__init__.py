# On-device acting engine: batched envs, population-vectorized collection,
# deterministic evaluation, and the fused collect->insert->sample->update
# train iteration (the acting-side half of the paper, alongside repro.pop).
from repro.rollout.vecenv import (  # noqa: F401
    VecEnv, VecEnvState, episode_stats, reset_stats,
)
from repro.rollout.collector import (  # noqa: F401
    Collector, exploration_policy, default_exploration,
)
from repro.rollout.evaluator import Evaluator  # noqa: F401
from repro.rollout.engine import RolloutEngine, transition_spec  # noqa: F401
