"""The fused, population-vectorized train iteration (paper §4 protocol).

PR 1 compiled the update side; this module compiles the *whole* iteration —
as ONE jitted function with buffer donation, so a training iteration never
leaves the device (no host round-trips between phases, which is where the
unfused loop loses its time; see ``benchmarks/actor_loop.py``).  What the
iteration does with experience depends on the agent's declared
``experience_kind`` (the ``repro.data.experience`` protocol), and the
engine builds the matching fused variant:

  replay (off-policy: td3 / sac / dqn / shared-critic)
      collect (scan over acting steps, vmapped over members)
        -> insert into the population of device-resident replay buffers
        -> sample num_steps batches per member
        -> num_steps chained update steps
      Updates are gated on ``buffer_can_sample`` with a ``lax.cond``: until
      every member's buffer holds ``batch_size`` transitions the iteration
      only collects (metrics come back zeroed, ``did_update`` False).

  trajectory (on-policy: ppo)
      collect (same scan, time-major, recording the policy's log_prob /
      value extras) -> store the fixed-length rollout
        -> GAE on device (per-member discount / gae_lambda hypers)
        -> epochs x shuffled minibatches, chained through the SAME update
           backend (vectorized / sequential / islands) as everything else
      There is no warm-up gate: a full rollout is always consumable, so
      ``did_update`` is always True.

Either way the update count per call is one ``num_steps``-chained (replay)
or ``epochs * minibatches``-chained (trajectory) backend call, and the
whole iteration is ONE jitted donated callable.

Consumers go through ``PopTrainer.attach_rollout(env, ...)`` /
``trainer.run_env_loop(iters)``; the engine itself owns the mutable
device-side pieces (buffers + env states) that are NOT part of the
checkpointed population state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vectorize import chain_steps
from repro.data.experience import (compute_gae, experience_ops, traj_add,
                                   traj_reset, transition_spec)
from repro.data.replay_buffer import buffer_sample
from repro.pop.backend import make_update
from repro.rollout.collector import Collector, default_exploration
from repro.rollout.evaluator import Evaluator
from repro.rollout.vecenv import VecEnv, episode_stats, reset_stats


class RolloutEngine:
    """Owns VecEnv states + the population experience buffers + the fused
    iteration.

    ``pcfg.backend`` picks the update implementation and ``pcfg.num_steps``
    the chained update count per iteration (replay kind; the trajectory
    kind derives its count from ``epochs`` x minibatches) — the same config
    knobs that drive ``PopTrainer.step``.
    """

    policy_lag = None   # serial engine; OverlapEngine overrides

    def __init__(self, agent, pcfg, env, *, key, init_state, hypers=None,
                 num_envs: int = 8, collect_steps: int = 32,
                 batch_size: int = 128, buffer_capacity: int = 100_000,
                 epochs: int = 4, eval_envs: int = 4,
                 eval_steps: int | None = None, explore_fn=None, mesh=None,
                 telemetry=None, chunk_steps: int | None = None):
        self.agent = agent
        self.telemetry = telemetry
        self.env = env
        self.n = pcfg.size
        self.num_envs = num_envs
        self.collect_steps = collect_steps
        self.batch_size = batch_size
        if chunk_steps is not None and collect_steps % chunk_steps:
            raise ValueError(f"chunk_steps={chunk_steps} must divide "
                             f"collect_steps={collect_steps}")
        self.chunk_steps = chunk_steps
        self.kind = getattr(agent, "experience_kind", "replay")
        self.exp = experience_ops(self.kind)

        explore_fn = explore_fn or default_exploration(agent)
        self.venv = VecEnv(env, num_envs)
        self.collector = Collector(self.venv, explore_fn)
        self.evaluator = Evaluator(env, explore_fn, num_envs=eval_envs,
                                   num_steps=eval_steps)

        k_env, _ = jax.random.split(key)
        self.vstate = self.collector.init(k_env, self.n)
        extras = getattr(agent, "experience_extras", ("log_prob", "value"))
        self.bufs = jax.vmap(lambda _: self.exp.init(
            env.spec, capacity=buffer_capacity, num_steps=collect_steps,
            num_envs=num_envs, extras=extras))(jnp.arange(self.n))

        if self.kind == "trajectory":
            if agent.population_level:
                raise ValueError("trajectory experience requires per-member "
                                 "agents (population-level updates consume "
                                 "replay batches)")
            rollout = collect_steps * num_envs
            if batch_size > rollout or rollout % batch_size:
                raise ValueError(
                    f"on-policy minibatch size {batch_size} must divide the "
                    f"rollout of collect_steps*num_envs = {rollout} "
                    f"transitions per member")
            self.epochs = max(1, epochs)
            self.minibatches = rollout // batch_size
            self.num_steps = self.epochs * self.minibatches
            defaults = getattr(agent, "default_hypers", {})
            self._gae_defaults = {
                "discount": defaults.get("discount", 0.99),
                "gae_lambda": defaults.get("gae_lambda", 0.95)}
        else:
            self.num_steps = max(1, pcfg.num_steps)

        if agent.population_level:
            # population_update consumes (N, B, ...) per call; chain K calls
            upd1 = make_update(agent, pcfg.backend, num_steps=1,
                               donate=False, mesh=mesh)
            self._update_k = (chain_steps(upd1, self.num_steps)
                              if self.num_steps > 1 else upd1)
        else:
            self._update_k = make_update(agent, pcfg.backend,
                                         num_steps=self.num_steps,
                                         donate=False, mesh=mesh)

        self.donate = pcfg.donate
        if self.kind == "replay":
            # the skip branch of the can-sample gate must return metrics of
            # the same structure as a real update — resolve shapes
            # abstractly once
            spec_t = transition_spec(env.spec)
            batch_s = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (self.num_steps, self.n, batch_size) + s.shape, s.dtype),
                spec_t)
            if self.num_steps == 1:
                batch_s = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                    batch_s)
            abstract = lambda t: jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), t)
            _, metrics_s = jax.eval_shape(
                self._update_k, abstract(init_state), batch_s,
                None if hypers is None else abstract(hypers))
            self._zero_metrics = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), metrics_s)
            iteration = self._build_offpolicy()
        else:
            iteration = self._build_onpolicy()

        self._iteration_fn = iteration   # un-jitted; build_epoch fuses it
        self._iteration = jax.jit(
            iteration, donate_argnums=(0, 1, 2) if pcfg.donate else ())
        # what iterate() actually calls: the jit wrapper, unless an
        # AOT-compiled executable was installed (warm_compile_async)
        self._iteration_exec = self._iteration

        if telemetry is not None and telemetry.enabled:
            # the acting-side shape of the run, once, so a log is
            # self-describing (env_steps_per_iteration contextualizes every
            # iter row's phase timings)
            telemetry.record(
                "engine", algo=type(agent).__name__, experience=self.kind,
                env=env.spec.name, population=self.n, num_envs=num_envs,
                collect_steps=collect_steps, batch_size=batch_size,
                num_steps=self.num_steps, chunk_steps=chunk_steps,
                policy_lag=self.policy_lag,
                env_steps_per_iteration=self.env_steps_per_iteration)

    # --------------------------------------------------------- collect side
    def _collect_insert(self, actors, bufs, vstate, hypers, kc):
        """Collect one iteration's experience and store it: the collect-then
        -add pair both fused iterations share.  With ``chunk_steps`` set the
        trajectory is folded into the store chunk-by-chunk
        (``Collector.collect_into``) so memory stays bounded by one chunk
        per member instead of ``collect_steps × num_envs`` transitions —
        bitwise-identical results either way.  Returns ``(bufs, vstate)``."""
        flat = self.kind == "replay"
        if self.chunk_steps is not None:
            if not flat:
                # on-policy: one rollout REPLACES the last (exp.add resets
                # then appends); chunked filling resets once, then appends
                bufs = jax.vmap(traj_reset)(bufs)
                add_fn = traj_add
            else:
                add_fn = self.exp.add
            vstate, bufs = self.collector.collect_into(
                actors, vstate, bufs, add_fn, kc, self.collect_steps,
                self.chunk_steps, hypers, flat=flat)
            return bufs, vstate
        vstate, traj = self.collector.collect(
            actors, vstate, kc, self.collect_steps, hypers, flat=flat)
        return jax.vmap(self.exp.add)(bufs, traj), vstate

    # ----------------------------------------------------- off-policy fused
    def _build_offpolicy(self):
        K, n, B = self.num_steps, self.n, self.batch_size

        def iteration(state, bufs, vstate, hypers, key):
            kc, ks = jax.random.split(key)
            actors = self.agent.actor_params(state)
            bufs, vstate = self._collect_insert(actors, bufs, vstate,
                                                hypers, kc)
            can = jnp.all(jax.vmap(
                lambda b: self.exp.ready(b, B))(bufs))

            def do_update(state):
                keys = jax.random.split(ks, K * n)
                keys = keys.reshape((K, n) + keys.shape[1:])
                batches = jax.vmap(jax.vmap(
                    lambda b, kk: buffer_sample(b, kk, B)),
                    in_axes=(None, 0))(bufs, keys)          # (K, N, B, ...)
                if K == 1:
                    batches = jax.tree.map(lambda x: x[0], batches)
                return self._update_k(state, batches, hypers)

            def skip(state):
                return state, self._zero_metrics

            state, metrics = jax.lax.cond(can, do_update, skip, state)
            return state, bufs, vstate, metrics, episode_stats(vstate), can

        return iteration

    # ------------------------------------------------------ on-policy fused
    def member_batches(self, mbuf, actor, mhypers, key):
        """One member's GAE + shuffled epoch/minibatch stack: the rollout
        ``(T, E, ...)`` becomes update batches ``(K, B, ...)`` with
        K = epochs * minibatches (jit-able; per-member args)."""
        d = mbuf.data
        T, E = self.collect_steps, self.num_envs
        D, B, K = T * E, self.batch_size, self.num_steps
        h = dict(self._gae_defaults)
        if mhypers:
            h = {**h, **{k: mhypers[k] for k in h if k in mhypers}}
        # V(s') is evaluated on the stored pre-reset next_obs, so a
        # truncated step still bootstraps while `done` zeroes true
        # terminals; `ep_end` cuts the lambda chain at either
        next_v = self.agent.value(actor, d["next_obs"])
        ep_end = jnp.maximum(d["done"], d["truncated"])
        adv, ret = compute_gae(d["reward"], d["value"], next_v,
                               d["done"], ep_end,
                               h["discount"], h["gae_lambda"])
        flat = {"obs": d["obs"], "action": d["action"],
                "log_prob": d["log_prob"], "value": d["value"],
                "advantage": adv, "return": ret}
        flat = jax.tree.map(lambda x: x.reshape((D,) + x.shape[2:]), flat)
        idx = jax.vmap(lambda k: jax.random.permutation(k, D))(
            jax.random.split(key, self.epochs))             # (epochs, D)
        idx = idx.reshape((K, B))
        return jax.tree.map(lambda x: x[idx], flat)         # (K, B, ...)

    def population_batches(self, bufs, actors, hypers, key):
        """The whole population's update batches in the chained layout
        ``(K, N, B, ...)`` (``(N, B, ...)`` when K == 1)."""
        keys = jax.random.split(key, self.n)
        if hypers is None:
            batches = jax.vmap(
                lambda b, a, k: self.member_batches(b, a, None, k))(
                    bufs, actors, keys)
        else:
            batches = jax.vmap(self.member_batches)(bufs, actors, hypers,
                                                    keys)
        batches = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batches)
        if self.num_steps == 1:
            batches = jax.tree.map(lambda x: x[0], batches)
        return batches

    def _build_onpolicy(self):
        def iteration(state, bufs, vstate, hypers, key):
            kc, kp = jax.random.split(key)
            actors = self.agent.actor_params(state)
            bufs, vstate = self._collect_insert(actors, bufs, vstate,
                                                hypers, kc)
            batches = self.population_batches(bufs, actors, hypers, kp)
            state, metrics = self._update_k(state, batches, hypers)
            return (state, bufs, vstate, metrics, episode_stats(vstate),
                    jnp.ones((), bool))

        return iteration

    # -------------------------------------------------- fused train–evolve
    def build_epoch(self, *, epoch_len: int, eval_every: int = 0,
                    evolve_fn=None, donate: bool | None = None):
        """Fuse an ENTIRE train–evolve epoch into one jitted donated call.

        ``epoch_len`` iterations run in a ``lax.scan`` over the un-jitted
        fused iteration; every ``eval_every``-th iteration additionally
        scores the population with the deterministic evaluator into an
        on-device fitness accumulator (``eval_every=0`` disables); after
        the scan, ``evolve_fn`` — a pure strategy step from
        ``EvolutionStrategy.evolve_fn()`` — exploits/explores on the
        epoch-mean fitness.  Nothing leaves the device: not the per-member
        parameters between iterations, not the fitness between evaluation
        and evolve, not the strategy's distribution state (threaded through
        as ``strat_state``).

        The key chain reproduces the unfused driver bitwise: one split per
        iteration, one extra split on evaluation iterations, one before the
        evolve — the exact sequence ``PopTrainer.env_iteration`` /
        ``evaluate_fitness`` / ``evolve`` performs eagerly.

        Returns the jitted

            epoch(state, bufs, vstate, hypers, strat_state, key) ->
                (state, bufs, vstate, hypers, strat_state, key,
                 metrics_stack, stats_stack, did_stack, evals, fitness,
                 lineage)

        where the stacks carry a leading ``(epoch_len,)`` axis, ``evals``
        is the ``(num_evals, N)`` per-evaluation fitness record, and
        ``fitness`` / ``lineage`` describe the evolve (identity lineage
        when ``evolve_fn`` is None).
        """
        iteration = self._iteration_fn
        evaluator = self.evaluator
        agent = self.agent
        n = self.n
        n_evals = (epoch_len // eval_every) if eval_every else 0
        if donate is None:
            donate = self.donate

        def epoch(state, bufs, vstate, hypers, strat_state, key):
            evals0 = jnp.zeros((max(n_evals, 1), n))

            def body(carry, i):
                state, bufs, vstate, key, evals = carry
                key, k_it = jax.random.split(key)
                state, bufs, vstate, metrics, stats, did = iteration(
                    state, bufs, vstate, hypers, k_it)
                if n_evals:
                    def do_eval(args):
                        key, evals = args
                        key, k_ev = jax.random.split(key)
                        fit = evaluator.evaluate(
                            agent.actor_params(state), k_ev)
                        return key, evals.at[
                            (i + 1) // eval_every - 1].set(fit)
                    key, evals = jax.lax.cond(
                        (i + 1) % eval_every == 0, do_eval,
                        lambda args: args, (key, evals))
                return ((state, bufs, vstate, key, evals),
                        (metrics, stats, did))

            carry0 = (state, bufs, vstate, key, evals0)
            (state, bufs, vstate, key, evals), (metrics, stats, dids) = \
                jax.lax.scan(body, carry0, jnp.arange(epoch_len))

            # the same reduction the trainer's fitness window performs:
            # mean over this epoch's evaluation rows, per member
            fitness = (jnp.mean(evals, axis=0) if n_evals
                       else jnp.zeros((n,)))
            if evolve_fn is not None:
                key, k_evolve = jax.random.split(key)
                state, hypers, lineage, strat_state = evolve_fn(
                    k_evolve, state, hypers, fitness, strat_state)
            else:
                lineage = jnp.arange(n)
            return (state, bufs, vstate, hypers, strat_state, key,
                    metrics, stats, dids, evals, fitness, lineage)

        return jax.jit(epoch, donate_argnums=(0, 1, 2) if donate else ())

    # ------------------------------------------------------------- stepping
    def iterate(self, state, hypers, key):
        """One fused train iteration; returns the new population state plus
        ``(metrics, episode_stats, did_update)``."""
        try:
            out = self._iteration_exec(state, self.bufs, self.vstate,
                                       hypers, key)
        except Exception:
            if self._iteration_exec is self._iteration:
                raise
            # an AOT executable only accepts the exact shapes it was
            # lowered for — fall back to the jit wrapper permanently
            self._iteration_exec = self._iteration
            out = self._iteration_exec(state, self.bufs, self.vstate,
                                       hypers, key)
        state, self.bufs, self.vstate, metrics, stats, did = out
        return state, metrics, stats, did

    # ---------------------------------------------------- AOT warm compile
    def warm_compile_async(self, state, hypers, key):
        """Start compiling the fused iteration ahead-of-time on a background
        thread (``jit(...).lower().compile()``) and return a ``join()``
        callable.  ``join()`` blocks until compilation finishes, installs
        the compiled executable as this engine's iteration (the lowered
        Compiled object does NOT populate the jit dispatch cache, so it must
        be kept and called directly), and returns the compile error if any
        (None on success — errors mean the engine just stays on the lazy jit
        path).

        This is the PR 3 residual closer: ``repro.elastic.restore_elastic``
        calls this before moving checkpoint data so the post-resize
        recompile overlaps the re-layout instead of serializing after it.
        """
        import threading

        abstract = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)), t)
        args = (abstract(state), abstract(self.bufs), abstract(self.vstate),
                None if hypers is None else abstract(hypers), abstract(key))
        box = {}

        def work():
            try:
                box["compiled"] = self._iteration.lower(*args).compile()
            except Exception as e:          # pragma: no cover - defensive
                box["error"] = e

        thread = threading.Thread(target=work, daemon=True,
                                  name="repro-aot-compile")
        thread.start()

        def join():
            thread.join()
            if "compiled" in box:
                self._iteration_exec = box["compiled"]
            return box.get("error")

        return join

    # -------------------------------------------------- elastic re-layout
    def export_state(self):
        """The engine's mutable device state — the population of experience
        buffers and the env states (with their episode accounting) — as one
        pytree, every leaf carrying the leading population axis, so
        ``repro.elastic`` can checkpoint it and gather it by member index
        across a resize."""
        return {"bufs": self.bufs, "vstate": self.vstate}

    def import_state(self, state):
        """Install what :meth:`export_state` produced (possibly restored
        from a checkpoint and resized to this engine's population)."""
        n = jax.tree.leaves(state["bufs"])[0].shape[0]
        if n != self.n:
            raise ValueError(f"rollout state holds {n} members but the "
                             f"engine was built for {self.n}; resize with "
                             f"repro.elastic.resize_tree first")
        self.bufs = jax.tree.map(jnp.asarray, state["bufs"])
        self.vstate = jax.tree.map(jnp.asarray, state["vstate"])

    @property
    def env_steps_per_iteration(self) -> int:
        return self.collect_steps * self.num_envs * self.n

    def reset_episode_stats(self):
        self.vstate = reset_stats(self.vstate)

    def probe_obs(self, key, size: int):
        """Recent-ish observations from member 0's experience (DvD behavior
        probes and similar diagnostics)."""
        buf0 = jax.tree.map(lambda x: x[0], self.bufs)
        if self.kind == "trajectory":
            obs = buf0.data["obs"]
            return obs.reshape((-1,) + obs.shape[2:])[:size]
        return buffer_sample(buf0, key, size)["obs"]
