"""The fused, population-vectorized train iteration (paper §4 protocol).

PR 1 compiled the update side; this module compiles the *whole* iteration:

    collect (scan over acting steps, vmapped over members)
      -> insert into the population of device-resident replay buffers
      -> sample num_steps batches per member
      -> num_steps chained update steps

as ONE jitted function with buffer donation, so a training iteration never
leaves the device — no host round-trips between the phases, which is where
the unfused loop loses its time (see ``benchmarks/actor_loop.py``).

Updates are gated on ``buffer_can_sample`` with a ``lax.cond``: until every
member's buffer holds ``batch_size`` transitions the iteration only
collects, and the update branch is skipped entirely (metrics come back
zeroed and ``did_update`` False).

Consumers go through ``PopTrainer.attach_rollout(env, ...)`` /
``trainer.run_env_loop(iters)``; the engine itself owns the mutable
device-side pieces (buffers + env states) that are NOT part of the
checkpointed population state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vectorize import chain_steps
from repro.data.replay_buffer import (buffer_add, buffer_can_sample,
                                      buffer_init, buffer_sample)
from repro.pop.backend import make_update
from repro.rollout.collector import Collector, default_exploration
from repro.rollout.evaluator import Evaluator
from repro.rollout.vecenv import VecEnv, episode_stats, reset_stats


def transition_spec(spec):
    """One replay-buffer item for an env spec (ShapeDtypeStructs)."""
    f32 = jnp.float32
    action = (jax.ShapeDtypeStruct((), jnp.int32) if spec.discrete
              else jax.ShapeDtypeStruct((spec.act_dim,), f32))
    return {"obs": jax.ShapeDtypeStruct((spec.obs_dim,), f32),
            "action": action,
            "reward": jax.ShapeDtypeStruct((), f32),
            "next_obs": jax.ShapeDtypeStruct((spec.obs_dim,), f32),
            "done": jax.ShapeDtypeStruct((), f32)}


class RolloutEngine:
    """Owns VecEnv states + population replay buffers + the fused iteration.

    ``pcfg.num_steps`` is the number of chained update steps per iteration
    and ``pcfg.backend`` picks the update implementation — the same config
    knobs that drive ``PopTrainer.step``.
    """

    def __init__(self, agent, pcfg, env, *, key, init_state, hypers=None,
                 num_envs: int = 8, collect_steps: int = 32,
                 batch_size: int = 128, buffer_capacity: int = 100_000,
                 eval_envs: int = 4, eval_steps: int | None = None,
                 explore_fn=None, mesh=None):
        self.agent = agent
        self.env = env
        self.n = pcfg.size
        self.num_steps = max(1, pcfg.num_steps)
        self.num_envs = num_envs
        self.collect_steps = collect_steps
        self.batch_size = batch_size

        explore_fn = explore_fn or default_exploration(agent)
        self.venv = VecEnv(env, num_envs)
        self.collector = Collector(self.venv, explore_fn)
        self.evaluator = Evaluator(env, explore_fn, num_envs=eval_envs,
                                   num_steps=eval_steps)

        k_env, _ = jax.random.split(key)
        self.vstate = self.collector.init(k_env, self.n)
        spec_t = transition_spec(env.spec)
        self.bufs = jax.vmap(lambda _: buffer_init(buffer_capacity, spec_t))(
            jnp.arange(self.n))

        if agent.population_level:
            # population_update consumes (N, B, ...) per call; chain K calls
            upd1 = make_update(agent, pcfg.backend, num_steps=1,
                               donate=False, mesh=mesh)
            self._update_k = (chain_steps(upd1, self.num_steps)
                              if self.num_steps > 1 else upd1)
        else:
            self._update_k = make_update(agent, pcfg.backend,
                                         num_steps=self.num_steps,
                                         donate=False, mesh=mesh)

        # the skip branch of the can-sample gate must return metrics of the
        # same structure as a real update — resolve shapes abstractly once
        batch_s = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (self.num_steps, self.n, batch_size) + s.shape, s.dtype),
            spec_t)
        if self.num_steps == 1:
            batch_s = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), batch_s)
        abstract = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), t)
        _, metrics_s = jax.eval_shape(
            self._update_k, abstract(init_state), batch_s,
            None if hypers is None else abstract(hypers))
        self._zero_metrics = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), metrics_s)

        self._iteration = jax.jit(
            self._build_iteration(),
            donate_argnums=(0, 1, 2) if pcfg.donate else ())

    # ------------------------------------------------------------ fused jit
    def _build_iteration(self):
        K, n, B = self.num_steps, self.n, self.batch_size

        def iteration(state, bufs, vstate, hypers, key):
            kc, ks = jax.random.split(key)
            actors = self.agent.actor_params(state)
            vstate, traj = self.collector.collect(
                actors, vstate, kc, self.collect_steps, hypers)
            bufs = jax.vmap(buffer_add)(bufs, traj)
            can = jnp.all(jax.vmap(
                lambda b: buffer_can_sample(b, B))(bufs))

            def do_update(state):
                keys = jax.random.split(ks, K * n)
                keys = keys.reshape((K, n) + keys.shape[1:])
                batches = jax.vmap(jax.vmap(
                    lambda b, kk: buffer_sample(b, kk, B)),
                    in_axes=(None, 0))(bufs, keys)          # (K, N, B, ...)
                if K == 1:
                    batches = jax.tree.map(lambda x: x[0], batches)
                return self._update_k(state, batches, hypers)

            def skip(state):
                return state, self._zero_metrics

            state, metrics = jax.lax.cond(can, do_update, skip, state)
            return state, bufs, vstate, metrics, episode_stats(vstate), can

        return iteration

    # ------------------------------------------------------------- stepping
    def iterate(self, state, hypers, key):
        """One fused train iteration; returns the new population state plus
        ``(metrics, episode_stats, did_update)``."""
        state, self.bufs, self.vstate, metrics, stats, did = \
            self._iteration(state, self.bufs, self.vstate, hypers, key)
        return state, metrics, stats, did

    # -------------------------------------------------- elastic re-layout
    def export_state(self):
        """The engine's mutable device state — the population of replay
        buffers and the env states (with their episode accounting) — as one
        pytree, every leaf carrying the leading population axis, so
        ``repro.elastic`` can checkpoint it and gather it by member index
        across a resize."""
        return {"bufs": self.bufs, "vstate": self.vstate}

    def import_state(self, state):
        """Install what :meth:`export_state` produced (possibly restored
        from a checkpoint and resized to this engine's population)."""
        n = jax.tree.leaves(state["bufs"])[0].shape[0]
        if n != self.n:
            raise ValueError(f"rollout state holds {n} members but the "
                             f"engine was built for {self.n}; resize with "
                             f"repro.elastic.resize_tree first")
        self.bufs = jax.tree.map(jnp.asarray, state["bufs"])
        self.vstate = jax.tree.map(jnp.asarray, state["vstate"])

    @property
    def env_steps_per_iteration(self) -> int:
        return self.collect_steps * self.num_envs * self.n

    def reset_episode_stats(self):
        self.vstate = reset_stats(self.vstate)

    def probe_obs(self, key, size: int):
        """Recent-ish observations from member 0's buffer (DvD behavior
        probes and similar diagnostics)."""
        buf0 = jax.tree.map(lambda x: x[0], self.bufs)
        return buffer_sample(buf0, key, size)["obs"]
