"""``VecEnv`` — ``num_envs`` copies of a pure-JAX env as one batched step.

One VecEnv holds the environments of ONE population member; the population
axis is added by ``Collector``/``Evaluator`` with an outer ``vmap``, giving
the (population × num_envs) leading axes the paper's acting phase runs over.

Episode accounting lives on device inside :class:`VecEnvState` so the host
never has to unpack trajectories to know how training is going: running
return/length per env, plus completed-episode aggregates (count, return sum,
length sum, last completed return) that ``episode_stats`` reduces to means.

Terminal observations follow the contract of ``repro.envs.core``: the
transition's ``next_obs`` is the pre-reset terminal observation (correct TD
bootstrapping) while ``state.obs`` — the next policy input — is the
post-auto-reset observation of the new episode.  Episode accounting counts
both terminations and time-limit truncations as episode ends, but the
transition's ``done`` stores termination only, so TD targets bootstrap
through truncations; ``truncated`` rides along separately because the
on-policy pipeline (GAE in ``repro.data.experience``) must additionally cut
its lambda chain at a time limit.  Each experience buffer stores only the
keys its spec declares, so the richer transition feeds every kind.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.core import Env


class VecEnvState(NamedTuple):
    env_state: Any                      # pytree, leaves (E, ...)
    obs: jnp.ndarray                    # (E, obs_dim) next policy input
    episode_return: jnp.ndarray         # (E,) running return, current episode
    episode_length: jnp.ndarray         # (E,) int32 running length
    completed_episodes: jnp.ndarray     # (E,) int32
    completed_return_sum: jnp.ndarray   # (E,)
    completed_length_sum: jnp.ndarray   # (E,) int32
    last_episode_return: jnp.ndarray    # (E,) return of latest finished ep


class VecEnv:
    def __init__(self, env: Env, num_envs: int):
        self.env = env
        self.num_envs = num_envs
        self.spec = env.spec

    def reset(self, key) -> VecEnvState:
        keys = jax.random.split(key, self.num_envs)
        env_state, obs = jax.vmap(self.env.reset)(keys)
        zf = jnp.zeros((self.num_envs,))
        zi = jnp.zeros((self.num_envs,), jnp.int32)
        return VecEnvState(env_state=env_state, obs=obs,
                           episode_return=zf, episode_length=zi,
                           completed_episodes=zi, completed_return_sum=zf,
                           completed_length_sum=zi, last_episode_return=zf)

    def step(self, state: VecEnvState, actions):
        """Batched step.  Returns ``(state, transition)`` where the
        transition dict is ready for ``buffer_add`` (leaves (E, ...))."""
        env_state, terminal_obs, reward, done, truncated = jax.vmap(
            self.env.step)(state.env_state, actions)
        ep_ret = state.episode_return + reward
        ep_len = state.episode_length + 1
        di = done.astype(jnp.int32)
        new = VecEnvState(
            env_state=env_state,
            obs=jax.vmap(self.env.observe)(env_state),
            episode_return=jnp.where(done, 0.0, ep_ret),
            episode_length=jnp.where(done, 0, ep_len),
            completed_episodes=state.completed_episodes + di,
            completed_return_sum=state.completed_return_sum
                + jnp.where(done, ep_ret, 0.0),
            completed_length_sum=state.completed_length_sum
                + jnp.where(done, ep_len, 0),
            last_episode_return=jnp.where(done, ep_ret,
                                          state.last_episode_return))
        transition = {"obs": state.obs, "action": actions, "reward": reward,
                      "next_obs": terminal_obs,
                      "done": (done & ~truncated).astype(jnp.float32),
                      "truncated": truncated.astype(jnp.float32)}
        return new, transition


def episode_stats(state: VecEnvState):
    """Completed-episode means, reduced over the env axis (works for both a
    single member, leaves (E,), and a stacked population, leaves (N, E) —
    the reduction is always over the trailing axis)."""
    count = state.completed_episodes.sum(-1)
    denom = jnp.maximum(count, 1).astype(jnp.float32)
    return {
        "episodes": count,
        "mean_return": state.completed_return_sum.sum(-1) / denom,
        "mean_length": state.completed_length_sum.sum(-1) / denom,
        "last_return": state.last_episode_return.mean(-1),
    }


def reset_stats(state: VecEnvState) -> VecEnvState:
    """Zero the completed-episode aggregates (fresh logging window) without
    disturbing the environments themselves."""
    zi = jnp.zeros_like(state.completed_episodes)
    return state._replace(completed_episodes=zi,
                          completed_return_sum=jnp.zeros_like(
                              state.completed_return_sum),
                          completed_length_sum=zi)
