"""Overlapped acting: the fused iteration split into two pipelined programs.

The serial engine (``repro.rollout.engine``) compiles collect -> insert ->
update as ONE program, so the device runs the phases strictly back-to-back
and the host blocks on the whole iteration whenever it needs a value (the
per-iteration fitness read every PBT/CEM driver performs).  This module
splits the iteration into two jitted programs —

    collect(actors, vstate, hypers, key) -> (vstate, slot, episode_stats)
    update(state, bufs, slot, hypers, key) -> (state, bufs, metrics, did)

— and software-pipelines them across iterations, exploiting JAX async
dispatch: by the time the host blocks on ``update(t)``'s results, acting
for iteration ``t+1`` is already enqueued behind it, so the device never
waits for the host and the host never waits for acting.  The ``slot`` —
one collect's worth of experience in flight between the two programs — is
double-buffered implicitly: collect writes a fresh slot while update
consumes (and with ``pcfg.donate`` donates) the previous one, so at most
two slots are ever alive.

``policy_lag`` pins the staleness semantics:

  ``lag=0`` — the parity anchor: collect(t) then update(t), sequentially,
      with the exact key-split order of the serial fused iteration
      (``kc, ks = split(key)``) — bitwise-identical results, pinned by
      ``tests/test_overlap.py`` across all four algorithms.
  ``lag=1`` — the overlapped fast path: update(t) consumes the slot
      collected at iteration t-1, i.e. the collector acts with params
      exactly ONE update behind the learner (the off-by-one property the
      tests pin).  For the off-policy kinds this is ordinary replay
      staleness; for PPO the stored per-step ``log_prob`` extras in
      ``trajectory_spec`` ARE the importance weights, so the clipped ratio
      re-weights the one-step-stale rollout exactly as designed.

The iteration-t schedule at ``lag=1`` (after a one-collect prologue) is

    1. capture ``actors(state_t)``           (host-side tree slice)
    2. dispatch update(t) on slot(t-1)       (device starts gradients)
    3. dispatch collect(t+1) with actors(state_t)
    4. return — the caller may block on update(t)'s metrics/fitness while
       collect(t+1) is still running on device

Donation: update donates (bufs, slot) but never ``state`` — the in-flight
collect still reads actor slices of the pre-update state; collect donates
``vstate``.  Staleness interactions (evolve rewrites params between
iterations; the pending slot was collected by pre-evolve actors) are the
same one-iteration staleness the knob already declares.

Not supported at ``lag=1``: ``build_epoch`` (a fused epoch is one program —
there is nothing to overlap) — use the serial engine for fused epochs.
``export_state`` drops the in-flight slot (one collect of not-yet-inserted
experience); a restore simply re-runs the prologue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.replay_buffer import buffer_sample
from repro.rollout.engine import RolloutEngine
from repro.rollout.vecenv import episode_stats


class OverlapEngine(RolloutEngine):
    """RolloutEngine with the iteration split into pipelined collect/update
    programs and a ``policy_lag`` staleness knob (0 = serial parity,
    1 = overlapped)."""

    def __init__(self, agent, pcfg, env, *, policy_lag: int = 1, **kwargs):
        if policy_lag not in (0, 1):
            raise ValueError(f"policy_lag must be 0 or 1, got {policy_lag}")
        self.policy_lag = policy_lag
        super().__init__(agent, pcfg, env, **kwargs)
        donate = pcfg.donate
        self._progs = {
            "collect": jax.jit(self._build_collect(),
                               donate_argnums=(1,) if donate else ()),
            "update": jax.jit(self._build_update(),
                              donate_argnums=(1, 2) if donate else ()),
        }
        self._exec = dict(self._progs)
        self._pending = None     # (slot, stats) in flight between programs

    # ---------------------------------------------------------- programs
    def _build_collect(self):
        flat = self.kind == "replay"

        def collect(actors, vstate, hypers, key):
            vstate, slot = self.collector.collect(
                actors, vstate, key, self.collect_steps, hypers, flat=flat,
                chunk_steps=self.chunk_steps)
            return vstate, slot, episode_stats(vstate)

        return collect

    def _build_update(self):
        if self.kind != "replay":
            def update(state, bufs, slot, hypers, key):
                bufs = jax.vmap(self.exp.add)(bufs, slot)
                # batches are built with the CURRENT params' actor slices
                # exactly like the serial iteration (which computes them
                # pre-update from the same state) — GAE's value baseline
                # matches the stored `value` extras' policy via `log_prob`
                actors = self.agent.actor_params(state)
                batches = self.population_batches(bufs, actors, hypers, key)
                state, metrics = self._update_k(state, batches, hypers)
                return state, bufs, metrics, jnp.ones((), bool)

            return update

        K, n, B = self.num_steps, self.n, self.batch_size

        def update(state, bufs, slot, hypers, key):
            bufs = jax.vmap(self.exp.add)(bufs, slot)
            can = jnp.all(jax.vmap(lambda b: self.exp.ready(b, B))(bufs))

            def do_update(state):
                keys = jax.random.split(key, K * n)
                keys = keys.reshape((K, n) + keys.shape[1:])
                batches = jax.vmap(jax.vmap(
                    lambda b, kk: buffer_sample(b, kk, B)),
                    in_axes=(None, 0))(bufs, keys)          # (K, N, B, ...)
                if K == 1:
                    batches = jax.tree.map(lambda x: x[0], batches)
                return self._update_k(state, batches, hypers)

            def skip(state):
                return state, self._zero_metrics

            state, metrics = jax.lax.cond(can, do_update, skip, state)
            return state, bufs, metrics, can

        return update

    def _call(self, which, *args):
        fn = self._exec[which]
        try:
            return fn(*args)
        except Exception:
            if fn is self._progs[which]:
                raise
            # AOT executables only accept the shapes they were lowered for
            self._exec[which] = self._progs[which]
            return self._progs[which](*args)

    # ---------------------------------------------------------- stepping
    def iterate(self, state, hypers, key):
        """One overlapped train iteration.  ``lag=0``: collect then update,
        bitwise-equal to the serial fused iteration.  ``lag=1``: update(t)
        on the pending slot is dispatched first, then collect(t+1) with the
        pre-update params — the returned ``(metrics, stats, did)`` belong
        to the consumed slot, and blocking on them does NOT wait for the
        in-flight collect."""
        if self.policy_lag == 0:
            kc, ks = jax.random.split(key)
            actors = self.agent.actor_params(state)
            self.vstate, slot, stats = self._call(
                "collect", actors, self.vstate, hypers, kc)
            state, self.bufs, metrics, did = self._call(
                "update", state, self.bufs, slot, hypers, ks)
            return state, metrics, stats, did

        if self._pending is None:
            # prologue: fill the first slot (one extra key split, once)
            key, kp = jax.random.split(key)
            actors = self.agent.actor_params(state)
            self.vstate, slot, stats = self._call(
                "collect", actors, self.vstate, hypers, kp)
            self._pending = (slot, stats)

        kc, ks = jax.random.split(key)
        actors = self.agent.actor_params(state)      # pre-update params
        slot, stats = self._pending
        new_state, self.bufs, metrics, did = self._call(
            "update", state, self.bufs, slot, hypers, ks)
        self.vstate, next_slot, next_stats = self._call(
            "collect", actors, self.vstate, hypers, kc)
        self._pending = (next_slot, next_stats)
        return new_state, metrics, stats, did

    # ------------------------------------------------------------- misc
    def build_epoch(self, **kwargs):
        if self.policy_lag == 0:
            return super().build_epoch(**kwargs)
        raise NotImplementedError(
            "fused train–evolve epochs are one jitted program — there is "
            "nothing to overlap; use the serial engine (policy_lag=None) "
            "or policy_lag=0 for fused epochs")

    def import_state(self, state):
        super().import_state(state)
        self._pending = None     # restored runs re-run the prologue

    # ------------------------------------------------- AOT warm compile
    def warm_compile_async(self, state, hypers, key):
        """AOT-compile BOTH pipelined programs on a background thread; the
        returned ``join()`` installs them (see the serial engine's
        docstring for the contract)."""
        import threading

        abstract = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)), t)
        a_state, a_bufs, a_vstate = (abstract(state), abstract(self.bufs),
                                     abstract(self.vstate))
        a_h = None if hypers is None else abstract(hypers)
        a_key = abstract(key)
        box = {}

        def work():
            try:
                a_actors = jax.eval_shape(self.agent.actor_params, a_state)
                _, a_slot, _ = jax.eval_shape(
                    self._progs["collect"], a_actors, a_vstate, a_h, a_key)
                box["collect"] = self._progs["collect"].lower(
                    a_actors, a_vstate, a_h, a_key).compile()
                box["update"] = self._progs["update"].lower(
                    a_state, a_bufs, a_slot, a_h, a_key).compile()
            except Exception as e:          # pragma: no cover - defensive
                box["error"] = e

        thread = threading.Thread(target=work, daemon=True,
                                  name="repro-aot-compile")
        thread.start()

        def join():
            thread.join()
            if "update" in box:
                self._exec = {"collect": box["collect"],
                              "update": box["update"]}
            return box.get("error")

        return join
