"""``Collector`` — the population-vectorized acting step (paper §4.1).

A ``lax.scan`` over ``num_steps`` acting steps, vmapped over the population:
each member drives its own ``num_envs`` environments with its own
exploration policy, whose noise scale comes from that member's dynamic
hyperparameters (the same dict the update step consumes).  By default
trajectories come back flattened to ``(N, num_steps * num_envs, ...)`` so
``vmap(buffer_add)`` inserts them straight into the population of
device-resident replay buffers; ``flat=False`` keeps them time-major
``(N, num_steps, num_envs, ...)`` for the on-policy pipeline (GAE needs the
time axis).

The exploration policy contract is
``policy_fn(actor_params, obs, key, hypers) -> actions`` OR
``-> (actions, extras)`` with per-member (unstacked) arguments; ``extras``
is a dict of per-env arrays (e.g. PPO's ``log_prob`` / ``value``) that the
collector records into the transition, because on-policy updates must see
the exact statistics of the distribution that sampled each action.
``exploration_policy`` builds a policy from the functional RL modules:
a module exposing ``explore`` (ppo) is used verbatim; otherwise
``hypers["explore_noise"]`` / ``hypers["epsilon"]`` route into the module's
exploration knob when the member tunes it.
"""
from __future__ import annotations

import jax

from repro.rollout.vecenv import VecEnv


def exploration_policy(module):
    """Exploration policy for a functional RL module, driven by per-member
    hypers.  A module exposing ``explore(params, obs, key, hypers)`` (the
    extras-emitting on-policy contract, e.g. ppo) is wrapped verbatim;
    otherwise td3-style modules expose additive-gaussian
    ``exploration_noise`` (hyper ``explore_noise``), dqn-style expose
    ``epsilon``; anything else (sac's stochastic policy) just consumes the
    key.

    ``explore_noise`` is deliberately its OWN hyper: td3's ``noise`` is the
    target-policy-smoothing sigma inside the critic update, and reusing it
    for acting would let PBT silently disable smoothing while trying to tune
    exploration.  It is still the fallback for loops that only tune
    ``noise``, with the module default as the last resort."""
    explore = getattr(module, "explore", None)
    if explore is not None:
        def fn(params, obs, key, hypers=None):
            return explore(params, obs, key, hypers)
        return fn
    defaults = getattr(module, "DEFAULT_HYPERS", {})
    if "noise" in defaults:
        def fn(params, obs, key, hypers=None):
            h = hypers if hypers else {}
            scale = h.get("explore_noise",
                          h.get("noise", defaults["noise"]))
            return module.policy(params, obs, key, exploration_noise=scale)
    elif "epsilon" in defaults:
        def fn(params, obs, key, hypers=None):
            h = hypers if hypers else {}
            eps = h.get("epsilon", defaults["epsilon"])
            return module.policy(params, obs, key, epsilon=eps)
    else:
        def fn(params, obs, key, hypers=None):
            return module.policy(params, obs, key)
    return fn


def default_exploration(agent):
    """Best exploration policy derivable from a ``repro.pop`` agent: its
    ``exploration_module`` (part of the Agent protocol) when it names one,
    else the agent's own deterministic-ish ``policy``."""
    module = getattr(agent, "exploration_module", None)
    if module is not None:
        return exploration_policy(module)
    return lambda params, obs, key, hypers=None: agent.policy(params, obs, key)


def split_actions(policy_out):
    """Normalize a policy result to ``(actions, extras_dict)``."""
    if isinstance(policy_out, tuple):
        return policy_out
    return policy_out, {}


class Collector:
    """Drives a population of actors through per-member :class:`VecEnv`s."""

    def __init__(self, venv: VecEnv, policy_fn):
        self.venv = venv
        self.policy_fn = policy_fn

    def init(self, key, n: int):
        """Population-stacked VecEnvState (leaves (N, E, ...))."""
        return jax.vmap(self.venv.reset)(jax.random.split(key, n))

    def collect(self, actors, vstate, key, num_steps: int, hypers=None,
                *, flat: bool = True):
        """Act ``num_steps`` batched steps.  Returns ``(vstate, traj)`` with
        traj leaves ``(N, num_steps * num_envs, ...)`` in insertion order
        (time-major per env so FIFO eviction drops oldest first), or
        time-major ``(N, num_steps, num_envs, ...)`` with ``flat=False``
        (the on-policy shape).  Any extras the policy emits are recorded
        alongside the transition fields.

        A population of 1 runs the member body directly (no outer vmap):
        same results, but XLA CPU compiles size-1-vmapped scans to
        pathologically slow code (~4x), and the paper's contract is that
        size 1 costs exactly one agent."""
        n = jax.tree.leaves(vstate)[0].shape[0]

        def member(actor, mvstate, mkey, mhypers):
            def body(carry, _):
                vs, k = carry
                k, ka = jax.random.split(k)
                actions, extras = split_actions(
                    self.policy_fn(actor, vs.obs, ka, mhypers))
                vs, trans = self.venv.step(vs, actions)
                return (vs, k), {**trans, **extras}

            (vs, _), traj = jax.lax.scan(body, (mvstate, mkey), None,
                                         length=num_steps)
            if flat:
                # (T, E, ...) -> (T*E, ...)
                traj = jax.tree.map(
                    lambda x: x.reshape((num_steps * self.venv.num_envs,)
                                        + x.shape[2:]), traj)
            return vs, traj

        member_keys = jax.random.split(key, n)
        if n == 1:
            one = lambda t: jax.tree.map(lambda x: x[0], t)
            vs, traj = member(one(actors), one(vstate), member_keys[0],
                              None if hypers is None else one(hypers))
            return jax.tree.map(lambda x: x[None], (vs, traj))
        return jax.vmap(member)(actors, vstate, member_keys, hypers)
