"""``Collector`` — the population-vectorized acting step (paper §4.1).

A ``lax.scan`` over ``num_steps`` acting steps, vmapped over the population:
each member drives its own ``num_envs`` environments with its own
exploration policy, whose noise scale comes from that member's dynamic
hyperparameters (the same dict the update step consumes).  By default
trajectories come back flattened to ``(N, num_steps * num_envs, ...)`` so
``vmap(buffer_add)`` inserts them straight into the population of
device-resident replay buffers; ``flat=False`` keeps them time-major
``(N, num_steps, num_envs, ...)`` for the on-policy pipeline (GAE needs the
time axis).

Chunked collection (GPU-sim scale): with thousands of envs per member the
materialized trajectory — ``num_steps × num_envs`` transitions per member —
is the memory high-water mark of the whole iteration.  ``chunk_steps``
re-shapes the scan into scan-of-scans (``num_steps // chunk_steps`` chunks
of ``chunk_steps``) so ``collect`` still returns the full trajectory with
an identical key chain, while ``collect_into`` folds each chunk straight
into the experience store (``add_fn``) and never materializes more than one
chunk — bitwise-identical to collect-then-add because the FIFO ring inserts
chunks at exactly the positions the whole-trajectory insert would use.

The exploration policy contract is
``policy_fn(actor_params, obs, key, hypers) -> actions`` OR
``-> (actions, extras)`` with per-member (unstacked) arguments; ``extras``
is a dict of per-env arrays (e.g. PPO's ``log_prob`` / ``value``) that the
collector records into the transition, because on-policy updates must see
the exact statistics of the distribution that sampled each action.
``exploration_policy`` builds a policy from the functional RL modules:
a module exposing ``explore`` (ppo) is used verbatim; otherwise
``hypers["explore_noise"]`` / ``hypers["epsilon"]`` route into the module's
exploration knob when the member tunes it.
"""
from __future__ import annotations

import jax

from repro.rollout.vecenv import VecEnv


def exploration_policy(module):
    """Exploration policy for a functional RL module, driven by per-member
    hypers.  A module exposing ``explore(params, obs, key, hypers)`` (the
    extras-emitting on-policy contract, e.g. ppo) is wrapped verbatim;
    otherwise td3-style modules expose additive-gaussian
    ``exploration_noise`` (hyper ``explore_noise``), dqn-style expose
    ``epsilon``; anything else (sac's stochastic policy) just consumes the
    key.

    ``explore_noise`` is deliberately its OWN hyper: td3's ``noise`` is the
    target-policy-smoothing sigma inside the critic update, and reusing it
    for acting would let PBT silently disable smoothing while trying to tune
    exploration.  It is still the fallback for loops that only tune
    ``noise``, with the module default as the last resort."""
    explore = getattr(module, "explore", None)
    if explore is not None:
        def fn(params, obs, key, hypers=None):
            return explore(params, obs, key, hypers)
        return fn
    defaults = getattr(module, "DEFAULT_HYPERS", {})
    if "noise" in defaults:
        def fn(params, obs, key, hypers=None):
            h = hypers if hypers else {}
            scale = h.get("explore_noise",
                          h.get("noise", defaults["noise"]))
            return module.policy(params, obs, key, exploration_noise=scale)
    elif "epsilon" in defaults:
        def fn(params, obs, key, hypers=None):
            h = hypers if hypers else {}
            eps = h.get("epsilon", defaults["epsilon"])
            return module.policy(params, obs, key, epsilon=eps)
    else:
        def fn(params, obs, key, hypers=None):
            return module.policy(params, obs, key)
    return fn


def default_exploration(agent):
    """Best exploration policy derivable from a ``repro.pop`` agent: its
    ``exploration_module`` (part of the Agent protocol) when it names one,
    else the agent's own deterministic-ish ``policy``."""
    module = getattr(agent, "exploration_module", None)
    if module is not None:
        return exploration_policy(module)
    return lambda params, obs, key, hypers=None: agent.policy(params, obs, key)


def split_actions(policy_out):
    """Normalize a policy result to ``(actions, extras_dict)``."""
    if isinstance(policy_out, tuple):
        return policy_out
    return policy_out, {}


class Collector:
    """Drives a population of actors through per-member :class:`VecEnv`s."""

    def __init__(self, venv: VecEnv, policy_fn):
        self.venv = venv
        self.policy_fn = policy_fn

    def init(self, key, n: int):
        """Population-stacked VecEnvState (leaves (N, E, ...))."""
        return jax.vmap(self.venv.reset)(jax.random.split(key, n))

    def _member_scan(self, actor, mvstate, mkey, mhypers, num_steps: int):
        """One member's acting scan: ``num_steps`` steps, one key split per
        step.  Returns ``(vstate, key, traj)`` with the carried key so
        chunked collection can continue the SAME split chain across chunks
        (the bitwise-parity anchor for chunking)."""
        def body(carry, _):
            vs, k = carry
            k, ka = jax.random.split(k)
            actions, extras = split_actions(
                self.policy_fn(actor, vs.obs, ka, mhypers))
            vs, trans = self.venv.step(vs, actions)
            return (vs, k), {**trans, **extras}

        (vs, k), traj = jax.lax.scan(body, (mvstate, mkey), None,
                                     length=num_steps)
        return vs, k, traj

    def _flatten(self, traj, num_steps: int):
        # (T, E, ...) -> (T*E, ...), time-major per env so FIFO eviction
        # drops oldest first
        return jax.tree.map(
            lambda x: x.reshape((num_steps * self.venv.num_envs,)
                                + x.shape[2:]), traj)

    @staticmethod
    def _chunks(num_steps: int, chunk_steps):
        if chunk_steps is None:
            return 1, num_steps
        if num_steps % chunk_steps:
            raise ValueError(
                f"chunk_steps={chunk_steps} must divide num_steps={num_steps}")
        return num_steps // chunk_steps, chunk_steps

    def collect(self, actors, vstate, key, num_steps: int, hypers=None,
                *, flat: bool = True, chunk_steps=None):
        """Act ``num_steps`` batched steps.  Returns ``(vstate, traj)`` with
        traj leaves ``(N, num_steps * num_envs, ...)`` in insertion order
        (time-major per env so FIFO eviction drops oldest first), or
        time-major ``(N, num_steps, num_envs, ...)`` with ``flat=False``
        (the on-policy shape).  Any extras the policy emits are recorded
        alongside the transition fields.  ``chunk_steps`` runs the scan as
        scan-of-scans (identical results; bounds the scan body for XLA) —
        to bound trajectory MEMORY too, use :meth:`collect_into`.

        A population of 1 runs the member body directly (no outer vmap):
        same results, but XLA CPU compiles size-1-vmapped scans to
        pathologically slow code (~4x), and the paper's contract is that
        size 1 costs exactly one agent."""
        n = jax.tree.leaves(vstate)[0].shape[0]
        n_chunks, chunk = self._chunks(num_steps, chunk_steps)

        def member(actor, mvstate, mkey, mhypers):
            if n_chunks == 1:
                vs, _, traj = self._member_scan(actor, mvstate, mkey,
                                                mhypers, num_steps)
            else:
                def outer(carry, _):
                    vs, k = carry
                    vs, k, traj = self._member_scan(actor, vs, k, mhypers,
                                                    chunk)
                    return (vs, k), traj

                (vs, _), traj = jax.lax.scan(outer, (mvstate, mkey), None,
                                             length=n_chunks)
                # (C, chunk, E, ...) -> (T, E, ...)
                traj = jax.tree.map(
                    lambda x: x.reshape((num_steps,) + x.shape[2:]), traj)
            return vs, self._flatten(traj, num_steps) if flat else traj

        member_keys = jax.random.split(key, n)
        if n == 1:
            one = lambda t: jax.tree.map(lambda x: x[0], t)
            vs, traj = member(one(actors), one(vstate), member_keys[0],
                              None if hypers is None else one(hypers))
            return jax.tree.map(lambda x: x[None], (vs, traj))
        return jax.vmap(member)(actors, vstate, member_keys, hypers)

    def collect_into(self, actors, vstate, bufs, add_fn, key, num_steps: int,
                     chunk_steps, hypers=None, *, flat: bool = True):
        """Chunked collect-and-store: act ``num_steps`` steps as
        ``num_steps // chunk_steps`` chunks, folding each chunk into the
        per-member experience store with ``add_fn(buf, chunk_traj)`` —
        memory stays bounded by ONE chunk per member instead of the whole
        trajectory.  Bitwise-identical to ``collect`` + one add: the key
        chain is the same (one carried key, one split per step) and FIFO /
        trajectory stores insert chunks at exactly the positions a single
        whole-trajectory insert would use.  Returns ``(vstate, bufs)``."""
        n = jax.tree.leaves(vstate)[0].shape[0]
        n_chunks, chunk = self._chunks(num_steps, chunk_steps)

        def member(actor, mvstate, mbuf, mkey, mhypers):
            def outer(carry, _):
                vs, buf, k = carry
                vs, k, traj = self._member_scan(actor, vs, k, mhypers, chunk)
                if flat:
                    traj = self._flatten(traj, chunk)
                return (vs, add_fn(buf, traj), k), None

            (vs, buf, _), _ = jax.lax.scan(outer, (mvstate, mbuf, mkey),
                                           None, length=n_chunks)
            return vs, buf

        member_keys = jax.random.split(key, n)
        if n == 1:
            one = lambda t: jax.tree.map(lambda x: x[0], t)
            vs, buf = member(one(actors), one(vstate), one(bufs),
                             member_keys[0],
                             None if hypers is None else one(hypers))
            return jax.tree.map(lambda x: x[None], (vs, buf))
        return jax.vmap(member)(actors, vstate, bufs, member_keys, hypers)
