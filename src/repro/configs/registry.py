"""Architecture registry: ``--arch <id>`` selection."""
from __future__ import annotations

import importlib

_ARCHS = (
    "musicgen_medium", "qwen3_moe_30b_a3b", "deepseek_v2_lite_16b",
    "pixtral_12b", "rwkv6_1_6b", "zamba2_7b", "qwen2_1_5b", "qwen3_8b",
    "gemma_7b", "qwen2_0_5b", "rwkv6_test",
)


def _mod_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def list_configs() -> list[str]:
    return [importlib.import_module(f"repro.configs.{m}").CONFIG.name
            for m in _ARCHS]


def get_config(arch_id: str):
    mod = _mod_name(arch_id)
    if mod not in _ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {list_configs()}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG
