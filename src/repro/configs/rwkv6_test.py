"""rwkv6-test [ssm] — tiny RWKV6 for CPU population-training tests.

Same family/block structure as rwkv6-1.6b, scaled to run a population of 8
through PopTrainer on one host: 2L d_model=64 vocab=256, fp32 master weights
(the fused population-Adam bitwise-parity tests need fp32 — the stock path
casts updates before the apply, the flattened path after, which only agree
exactly on fp32 params), no remat, chunk 16 so seq_len 32 takes the chunked
WKV path.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="rwkv6-test", family="ssm",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    block_type="rwkv6", ssm_head_dim=32,
    ssm_chunk=16, dtype="float32", remat=False,
)
