"""rwkv6-1.6b [ssm] — Finch, data-dependent decay; attention-free.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified]
Sub-quadratic: runs the long_500k shape.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    block_type="rwkv6", ssm_head_dim=64,
    ssm_chunk=64, ssm_compute_dtype="bfloat16",  # §Perf (same fix as zamba2)
)
