"""qwen3-moe-30b-a3b [moe] — 128 experts top-8.

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per-expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import LMConfig, MoESpec

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
    moe=MoESpec(num_experts=128, top_k=8, d_expert=768),
)
