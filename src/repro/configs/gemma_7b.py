"""gemma-7b [dense] — GeGLU, head_dim=256.

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf].  Embeddings scaled by sqrt(d_model), tied head.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256,
    activation="gelu", tie_embeddings=True,
)
