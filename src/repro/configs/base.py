"""Config dataclasses: model architectures, input shapes, population/PBT.

All configs are frozen dataclasses → hashable → usable as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    group_size: int = 256   # per-group capacity keeps dispatch memory O(T*k*cf)
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLASpec:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    activation: str = "silu"       # silu -> SwiGLU, gelu -> GeGLU
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    block_type: str = "attention"  # attention | rwkv6 | mamba2
    ssm_state: int = 0
    ssm_head_dim: int = 64
    shared_attn_every: int = 0     # zamba2: shared attn block period (0 = off)
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    frontend: str = "none"         # none | audio_frames | vision_patches
    num_frontend_positions: int = 0
    dtype: str = "bfloat16"
    remat: bool = True
    use_chunked: bool = True       # chunked SSM/WKV path (vs literal scan)
    ssm_chunk: int = 128           # SSD/WKV chunk length
    ssm_compute_dtype: str = "float32"  # intra-chunk einsum dtype (perf knob)
    logits_chunk: int = 0          # >0: chunk the loss over the seq axis
    use_flash: bool = False        # Pallas flash attention (TPU only)
    use_kernels: Optional[bool] = None  # kernels/ops dispatch: None = auto
                                        # (TPU, non-differentiated forwards)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True iff long-context (500k) decode is supported (see DESIGN.md)."""
        return self.block_type in ("rwkv6", "mamba2")

    def replace(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "LMConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 2 if self.shared_attn_every == 0 else 8),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim else None,
            dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=64,
                num_shared=min(self.moe.num_shared, 1), group_size=64)
        if self.mla is not None:
            kw["mla"] = MLASpec(kv_lora_rank=32, qk_nope_dim=16,
                                qk_rope_dim=8, v_dim=16)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 4
        if self.num_frontend_positions:
            kw["num_frontend_positions"] = 8
        if self.block_type in ("rwkv6", "mamba2"):
            kw["ssm_head_dim"] = 32
            kw["ssm_state"] = 16 if self.block_type == "mamba2" else 0
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: LMConfig) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


@dataclass(frozen=True)
class HyperSpace:
    """Per-hyperparameter prior: log-uniform or uniform ranges (paper §B.1)."""
    log_uniform: tuple = ()   # ((name, lo, hi), ...)
    uniform: tuple = ()       # ((name, lo, hi), ...)

    @property
    def names(self):
        return tuple(n for n, _, _ in self.log_uniform) + \
               tuple(n for n, _, _ in self.uniform)


@dataclass(frozen=True)
class PopulationConfig:
    """The paper's technique as a first-class config feature.

    ``strategy`` and ``backend`` are the two one-line knobs of the unified
    ``repro.pop`` API: strategy in {none, pbt, cem, dvd} picks the outer
    evolution loop (size 1 always degrades to none), backend in
    {vectorized, sequential, sharded} picks how the update executes.
    """
    size: int = 1
    strategy: str = "pbt"                # repro.pop.STRATEGIES key
    backend: str = "vectorized"          # repro.pop.BACKENDS key
    num_steps: int = 1                   # chained update steps per call (§4.1)
    donate: bool = True                  # donate population buffers under jit
    fused_adam: bool = False             # kernels/pop_adam for population-
                                         # level optimizer steps (TPU; jnp
                                         # fallback elsewhere)
    fused_linear: bool = False           # kernels/pop_matmul for population-
                                         # batched linear layers inside the
                                         # fused update (needs fused_adam)
    pbt_interval: int = 100_000          # trainer steps between evolve calls
    exploit_frac: float = 0.3            # paper §B.1: bottom/top 30%
    perturb_prob: float = 0.5            # resample vs perturb
    perturb_scale: float = 1.2
    hyper_space: HyperSpace = field(default_factory=HyperSpace)
    fitness_window: int = 10             # last-k episode returns / -loss window
    # CEM strategy (paper §5.2 / B.2)
    elite_frac: float = 0.5
    sigma_init: float = 1e-2
    cem_noise_init: float = 1e-2
    cem_noise_decay: float = 0.999
    # DvD strategy (§B.2 coefficient schedule)
    dvd_period: int = 20_000


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    seed: int = 0
    population: PopulationConfig = field(default_factory=PopulationConfig)
    grad_compression: str = "none"       # none | int8
    grad_accum: int = 1                  # microbatches per optimizer step
