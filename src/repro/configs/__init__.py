from repro.configs.base import (  # noqa: F401
    LMConfig, MoESpec, MLASpec, ShapeSpec, LM_SHAPES, applicable_shapes,
    HyperSpace, PopulationConfig, TrainConfig,
)
from repro.configs.registry import get_config, list_configs  # noqa: F401
