"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified].  One weight-shared attention+MLP block is
invoked every 6 Mamba2 layers (13 full super-blocks + a 3-layer tail);
each invocation has its own KV cache.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    block_type="mamba2", ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6,
    # §Perf: bf16 intra-chunk SSD + chunk 64 (see EXPERIMENTS.md zamba2 log)
    ssm_chunk=256, ssm_compute_dtype="bfloat16",
)
