"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo decoder backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified].  Backbone only: the ViT is a
stub — ``input_specs`` provides precomputed patch embeddings for a
256-position image prefix.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, rope_theta=1e9,
    frontend="vision_patches", num_frontend_positions=256,
)
