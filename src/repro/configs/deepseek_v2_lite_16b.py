"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed experts.

27L d_model=2048 16H d_ff=1408 (per-expert) vocab=102400, MoE 64e top-6
[arXiv:2405.04434; hf].  See DESIGN.md for the 64-vs-160 routed-expert
discrepancy in the assignment line (we follow the bracketed spec: 64 routed,
top-6, +2 shared); first layer is dense as in the released model.
"""
from repro.configs.base import LMConfig, MoESpec, MLASpec

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoESpec(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                first_dense_layers=1),
    mla=MLASpec(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
)
