"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf]
Backbone only: the EnCodec frontend is a stub — ``input_specs`` provides
precomputed frame embeddings (B,S,1536); the head is the 2048-way codebook.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    activation="gelu",              # MusicGen uses GELU MLPs
    frontend="audio_frames",
)
