"""``ContinuousEvaluator`` — promotion/demotion from live checkpoints.

Training and serving share one artifact: the checkpoint directory that
``PopTrainer.save`` keeps appending to.  This module is the serving side's
watcher: every new checkpoint step it reads the cheap JSON extras
(``CheckpointManager.peek_extra`` — per-member fitness, population size,
step, no array IO), loads ONLY the stacked actor params (the ``"actors"``
aux tree, restored against an agent-derived template — never the
optimizer states, strategy internals or replay buffers, so promotion
costs actor-bytes, not a full trainer restore), embeds every member's
behavior on a fixed probe batch, and reselects the serving set by
fitness + DvD diversity (:func:`repro.serve.ensemble.select_members`).

The promotion policy is deliberately simple and total: the latest
checkpoint always wins (its params are fresher even when membership is
unchanged), and membership changes are reported as promote/demote events
so an operator can audit WHY traffic moved.  Members leave the set only by
losing their slot to a better candidate — there is no partial update,
because the selection is a joint (fitness + ensemble-volume) optimum, not
k independent rankings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvd import behavior_embedding
from repro.serve.ensemble import ServingSet, make_serving_set, select_members
from repro.serve.forward import PolicyForward


def probe_observations(env, key, size: int = 32):
    """A fixed batch of reset observations — the shared probe states every
    member is embedded on (same role as DvD's training-time probes)."""
    _, obs = jax.vmap(env.reset)(jax.random.split(key, size))
    return obs


def load_actor_stack(manager, agent, *, step: int | None = None):
    """The stacked actor params + extras of a checkpoint, WITHOUT a full
    trainer restore: ``peek_extra`` supplies size/fitness/step from JSON,
    and the ``"actors"`` aux tree restores against a template built from
    nothing but the agent (``agent.population_init`` shapes the structure;
    the saved arrays supply the values).  Raises on checkpoints written
    before ``PopTrainer.save`` recorded actors — serving needs the
    producer's format, and a silent fallback to a full restore would hide
    that the cheap path regressed."""
    step = manager.latest() if step is None else step
    if step is None:
        raise FileNotFoundError(
            f"load_actor_stack: no checkpoint in {manager.dir}")
    extra = manager.peek_extra(step)
    n = extra["size"]
    template = agent.actor_params(
        agent.population_init(jax.random.PRNGKey(0), n))
    actors = manager.restore_aux("actors", template, step)
    if actors is None:
        raise ValueError(
            f"checkpoint step {step} in {manager.dir} has no 'actors' aux "
            f"tree — it was written before PopTrainer.save recorded the "
            f"serving params; re-save with the current trainer (one "
            f"trainer.save() call) to make it servable")
    return jax.tree.map(jnp.asarray, actors), extra


class ContinuousEvaluator:
    """Watches a checkpoint directory and keeps a :class:`ServingSet`
    promoted from the freshest population.

    ``size`` is the ensemble size; ``probe_obs`` the shared probe batch for
    behavioral embeddings (None selects on fitness alone);
    ``diversity_weight`` trades nats of DvD ensemble volume against
    standard deviations of fitness (0 = pure fitness ranking).
    """

    def __init__(self, manager, agent, *, size: int = 4, probe_obs=None,
                 diversity_weight: float = 1.0, length_scale: float = 1.0,
                 forward: PolicyForward | None = None, telemetry=None):
        self.mgr = manager
        self.agent = agent
        self.size = size
        self.probe_obs = probe_obs
        self.diversity_weight = diversity_weight
        self.length_scale = length_scale
        self.forward = forward if forward is not None \
            else PolicyForward.for_agent(agent)
        self.serving: ServingSet | None = None
        # in-memory audit trail, PLUS — when a telemetry object is given —
        # every event persisted as a "promotion" row, so a served
        # ensemble's provenance survives process restart instead of dying
        # with this list
        self.events: list[dict] = []
        self.telemetry = telemetry
        self._last_step: int | None = None

    def select(self, actors, fitness) -> np.ndarray:
        """The promotion criterion on a loaded actor stack: fitness + DvD
        diversity over probe-behavior embeddings."""
        n = jax.tree.leaves(actors)[0].shape[0]
        emb = None
        if self.probe_obs is not None:
            emb = np.asarray(behavior_embedding(
                self.forward.member, actors, self.probe_obs), np.float64)
        if fitness is None and emb is None:
            import warnings
            warnings.warn(
                "ContinuousEvaluator: checkpoint carries no fitness (saved "
                "right after an evolve) and no probe_obs was given; "
                "promoting by member index", stacklevel=2)
            return np.arange(min(self.size, n), dtype=np.int64)
        return select_members(fitness, emb, self.size,
                              diversity_weight=self.diversity_weight,
                              length_scale=self.length_scale)

    def poll(self, server=None) -> ServingSet | None:
        """Promote from the latest checkpoint if it is newer than the one
        currently serving.  Returns the new :class:`ServingSet` (installed
        into ``server`` when given), or None when nothing changed.  Each
        membership change is appended to ``self.events`` as
        ``{"step", "promoted", "demoted", "members"}``."""
        step = self.mgr.latest()
        if step is None or step == self._last_step:
            return None
        actors, extra = load_actor_stack(self.mgr, self.agent, step=step)
        fitness = extra["fitness"]
        members = self.select(actors, fitness)
        new = make_serving_set(actors, members, step=step, fitness=fitness,
                               meta={"population": extra["size"]})
        old = set() if self.serving is None else set(
            self.serving.members.tolist())
        now = set(members.tolist())
        event = {
            "step": step,
            "promoted": sorted(now - old),
            "demoted": sorted(old - now),
            "members": members.tolist(),
        }
        self.events.append(event)
        if self.telemetry is not None:
            self.telemetry.record(
                "promotion", **event,
                fitness=None if fitness is None else list(fitness),
                population=extra["size"])
        self.serving = new
        self._last_step = step
        if server is not None:
            server.install(new)
        return new
