"""``repro.serve`` — population-as-ensemble inference.

The serving counterpart of the training stack: a trained population is an
ensemble, and the paper's one-compiled-call-for-N-members protocol serves
it as cheaply as it trained it.

  * :mod:`repro.serve.forward`    — :class:`PolicyForward`, the ONE
    deterministic policy forward shared (bit-exactly) with the
    training-time ``repro.rollout.Evaluator``.
  * :mod:`repro.serve.ensemble`   — :class:`ServingSet` +
    :func:`select_members`: fitness + DvD-diversity greedy selection of
    which members earn an inference slot.
  * :mod:`repro.serve.continuous` — :class:`ContinuousEvaluator`: watch a
    live checkpoint dir, load only the actor stack (``peek_extra`` +
    ``"actors"`` aux, no full trainer restore), promote/demote.
  * :mod:`repro.serve.server`     — :class:`BatchServer`: pad/batch
    requests, run every member's forward + the mean/vote/best reduction as
    ONE jitted donated call, ``shard_map``'d over islands when the
    ensemble outgrows a device.

Worked example (serve what ``launch/train.py`` trained)::

    from repro.checkpoint import CheckpointManager
    from repro.envs import make
    from repro.rl import make_agent
    from repro.serve import (BatchServer, ContinuousEvaluator,
                             PolicyForward, probe_observations)

    env = make("pendulum")
    agent = make_agent("td3", env.spec)
    watcher = ContinuousEvaluator(
        CheckpointManager("/tmp/repro_ckpt"), agent, size=4,
        probe_obs=probe_observations(env, jax.random.PRNGKey(0), 32))
    server = BatchServer(watcher.forward, env.spec, watcher.poll(),
                         max_batch=256, mode="mean")
    actions = server.serve(obs_batch)       # one jitted ensemble call
    watcher.poll(server)                    # promote newer checkpoints
"""
from repro.serve.forward import PolicyForward  # noqa: F401
from repro.serve.ensemble import (  # noqa: F401
    ServingSet, make_serving_set, select_members,
)
from repro.serve.continuous import (  # noqa: F401
    ContinuousEvaluator, load_actor_stack, probe_observations,
)
from repro.serve.server import BatchServer  # noqa: F401
