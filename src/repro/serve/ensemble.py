"""``ServingSet`` — which members of a trained population serve traffic.

The population IS the ensemble: training leaves behind N members, and the
serving engine picks the ``k`` that are worth an inference slot.  Fitness
alone is the wrong criterion — PBT populations converge, and an ensemble
of near-clones buys nothing over its best member — so selection follows
Effective Diversity (DvD, Parker-Holder et al.): maximize fitness PLUS the
log-determinant volume of the RBF kernel of behavioral embeddings, the
exact matrix ``repro.core.dvd`` trains with.  Greedy forward selection is
(provably, by submodularity of log det) near-optimal and runs on host in
O(k·N) small determinants — this is control-plane math that happens once
per promotion, never per request.

``ServingSet`` is the immutable result: the chosen member indices, their
stacked actor params (gathered out of the checkpointed population), the
fitness that justified them, and which of them is the single best member
(the ``"best"`` reduction mode's pick).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.dvd import rbf_kernel


def _logdet(k: np.ndarray) -> float:
    sign, logdet = np.linalg.slogdet(k)
    return float(logdet)


def select_members(fitness, embeddings, k: int, *,
                   diversity_weight: float = 1.0,
                   length_scale: float = 1.0) -> np.ndarray:
    """Pick ``k`` member indices by fitness + DvD diversity gain.

    ``fitness`` is (N,) or None (a checkpoint written right after an evolve
    carries none — selection then runs on diversity alone).  ``embeddings``
    is the (N, E) behavioral-embedding matrix
    (``repro.core.dvd.behavior_embedding`` on a shared probe batch) or None
    to select on fitness alone.  Fitness is z-normalized so
    ``diversity_weight`` trades nats of ensemble volume against standard
    deviations of fitness, independent of the env's return scale.

    The fittest member is always selected first — whatever the diversity
    term says, the serving set must contain the best policy we have — and
    each further slot goes to the candidate maximizing
    ``z_fitness + diversity_weight * (logdet K[S+c] - logdet K[S])``.
    """
    if fitness is None and embeddings is None:
        raise ValueError("select_members needs fitness and/or embeddings; "
                         "got neither")
    n = len(fitness) if fitness is not None else len(embeddings)
    k = max(1, min(k, n))
    if fitness is not None:
        fit = np.asarray(fitness, np.float64)
        std = fit.std()
        z = (fit - fit.mean()) / (std if std > 0 else 1.0)
    else:
        z = np.zeros((n,))
    if embeddings is None:
        return np.argsort(-z, kind="stable")[:k].astype(np.int64)

    emb = np.asarray(embeddings, np.float64)
    kern = np.asarray(rbf_kernel(emb, length_scale=length_scale))
    selected = [int(np.argmax(z))]
    while len(selected) < k:
        base = _logdet(kern[np.ix_(selected, selected)])
        best_c, best_score = None, -np.inf
        for c in range(n):
            if c in selected:
                continue
            trial = selected + [c]
            gain = _logdet(kern[np.ix_(trial, trial)]) - base
            score = z[c] + diversity_weight * gain
            if score > best_score:
                best_c, best_score = c, score
        selected.append(best_c)
    return np.asarray(selected, np.int64)


@dataclass(frozen=True)
class ServingSet:
    """The members currently serving traffic.

    ``members[i]`` is the checkpoint-population index behind ensemble slot
    ``i``; ``params`` is the (k,)-stacked actor tree gathered in that
    order; ``best`` is the slot (not the population index) holding the
    fittest member, which the ``"best"`` reduction serves.  ``step`` is the
    checkpoint step the set was promoted from — the serving engine's
    version number.
    """
    step: int
    members: np.ndarray                 # (k,) population indices
    params: Any                         # stacked actor pytree, leaves (k, ...)
    fitness: np.ndarray | None = None   # (k,) fitness per slot, or None
    best: int = 0                       # slot index of the fittest member
    meta: dict = field(default_factory=dict)

    @property
    def size(self) -> int:
        return len(self.members)

    def describe(self) -> str:
        fit = ("none" if self.fitness is None
               else np.asarray(self.fitness).round(2).tolist())
        return (f"ServingSet(step={self.step}, "
                f"members={self.members.tolist()}, fitness={fit}, "
                f"best=slot {self.best})")


def make_serving_set(actors, members, *, step: int = -1, fitness=None,
                     meta=None) -> ServingSet:
    """Gather ``members`` (population indices) out of a stacked actor tree
    into a :class:`ServingSet` — the promotion primitive
    ``ContinuousEvaluator`` and the benchmarks share."""
    import jax

    members = np.asarray(members, np.int64)
    params = jax.tree.map(lambda x: x[members], actors)
    fit = None
    if fitness is not None:
        fit = np.asarray(fitness, np.float64)[members]
    best = 0 if fit is None else int(np.argmax(fit))
    return ServingSet(step=step, members=members, params=params,
                      fitness=fit, best=best, meta=dict(meta or {}))
