"""``BatchServer`` — population-as-ensemble inference in ONE jitted call.

The paper's training claim — vectorize the whole population and one
compiled call costs ~one member — applies unchanged to inference: requests
are padded to a fixed batch, broadcast across the member axis, and every
ensemble member's deterministic forward runs inside one jitted, donated
executable (``vmap`` over members, exactly like the training backends).
The reduction across members is part of the same program, so an ensemble
answer costs one dispatch, not ``k``:

  * ``mean`` — average the member actions (continuous); for discrete
    action spaces this is plurality weight, i.e. identical to ``vote``.
  * ``vote`` — majority vote over the members' greedy actions (discrete).
  * ``best`` — the single fittest member's action (the ensemble as a hot
    standby: promotion picks WHO is best, serving stays one program).

Population bigger than one device: pass an ``IslandLayout`` mesh and the
member axis is ``shard_map``'d over the ``"pop"`` axis — each island runs
its own member block's forward, the reduction is the only cross-island
collective, and the call is still one jitted program (the serving mirror
of the ``"islands"`` update backend).

Donation: the *request buffer* is donated (a request batch is consumed by
its answer — XLA reuses it for the output), never the params (they must
survive for the next request).  After warm-up a call moves no bytes
between host and device except the explicit request ingress/egress;
``tests/test_serve.py`` pins that with ``jax.transfer_guard``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.serve.ensemble import ServingSet
from repro.serve.forward import PolicyForward
from repro.telemetry import LatencyWindow

MODES = ("mean", "vote", "best")


class BatchServer:
    """Pads/batches observation requests and answers them with the
    ensemble.

    ``forward`` is the shared :class:`PolicyForward`; ``spec`` the
    ``repro.envs`` EnvSpec (discrete-ness and action arity decide what the
    reductions mean); ``serving_set`` the initial
    :class:`~repro.serve.ensemble.ServingSet` (install more via
    :meth:`install` as the ``ContinuousEvaluator`` promotes).  A new set of
    the SAME ensemble size reuses the compiled executable; a different size
    recompiles once (promotions are control-plane rare).
    """

    def __init__(self, forward: PolicyForward, spec, serving_set=None, *,
                 max_batch: int = 256, mode: str = "mean", mesh=None,
                 donate: bool = True, telemetry=None,
                 telemetry_every: int = 100):
        if mode not in MODES:
            raise ValueError(f"unknown reduction mode {mode!r}; one of "
                             f"{MODES}")
        if mode == "vote" and not spec.discrete:
            raise ValueError(
                f"mode='vote' needs a discrete action space but env "
                f"{spec.name!r} is continuous; use 'mean' or 'best'")
        self.forward = forward
        self.spec = spec
        self.mode = mode
        self.max_batch = max_batch
        self.mesh = mesh
        self.set: ServingSet | None = None
        self._pending: list = []
        self.requests_served = 0
        # serving telemetry: per-request-batch latency histogram + batch
        # fill ratio + queue depth, summarized into one "serve" row every
        # ``telemetry_every`` served batches.  All host-side bookkeeping
        # around the jitted call — the hot path itself is untouched (the
        # transfer-guard test runs with a live sink attached).
        self.telemetry = telemetry
        self.telemetry_every = max(1, telemetry_every)
        self._window = LatencyWindow()
        self._recording = True

        members_fn = forward.members
        self._request_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            members_fn = compat.shard_map(
                forward.members, mesh=mesh,
                in_specs=(P("pop"), P()), out_specs=P("pop"))
            # requests enter replicated over the mesh; placing them there
            # explicitly keeps the hot path free of implicit reshards
            self._request_sharding = NamedSharding(mesh, P())

        def infer(params, best, obs):
            acts = members_fn(params, obs)              # (M, B, ...)
            if mode == "best":
                return jnp.take(acts, best, axis=0)
            if spec.discrete:
                # mean == vote on a discrete space: plurality of the
                # members' greedy actions
                votes = jax.nn.one_hot(acts, spec.act_dim).sum(0)
                return jnp.argmax(votes, axis=-1).astype(acts.dtype)
            return acts.mean(0)

        self._infer = jax.jit(infer, donate_argnums=(2,) if donate else ())
        if serving_set is not None:
            self.install(serving_set)

    # ---------------------------------------------------------- promotion
    def install(self, serving_set: ServingSet):
        """Swap the ensemble (a ``ContinuousEvaluator`` promotion).  With
        an islands mesh the member axis must tile the islands, same rule as
        the training backend."""
        if self.mesh is not None:
            islands = self.mesh.shape["pop"]
            if serving_set.size % islands:
                raise ValueError(
                    f"serving set of {serving_set.size} members does not "
                    f"split over {islands} islands; pick an ensemble size "
                    f"the mesh tiles")
        self.set = serving_set
        self._params = self._place(serving_set.params)
        self._best = jnp.asarray(serving_set.best, jnp.int32)
        return self

    def _place(self, params):
        if self.mesh is None:
            return jax.device_put(params)
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = NamedSharding(self.mesh, P("pop"))
        return jax.device_put(params, jax.tree.map(lambda _: sh, params))

    # ------------------------------------------------------------ serving
    def warmup(self):
        """Compile the ensemble executable before the first real request
        (one padded batch of zeros).  XLA warns when the donated request
        buffer can't alias the action output (obs_dim != act_dim — donation
        then just releases the buffer early instead of reusing it); that
        compile-time note is expected and silenced here so serving logs
        stay clean."""
        import warnings

        self._recording = False   # a compile is not a latency sample
        try:
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                self.serve(np.zeros((1, self.spec.obs_dim), np.float32))
        finally:
            self._recording = True
        return self

    def place_request(self, obs):
        """Explicit request ingress: a device-resident buffer with the
        executable's input sharding (replicated over the mesh on the
        islands path, plain placement otherwise).  This is the ONLY
        transfer a request pays — everything after it runs under
        ``transfer_guard('disallow')``."""
        if self._request_sharding is None:
            return jax.device_put(obs)
        return jax.device_put(obs, self._request_sharding)

    def infer_device(self, obs):
        """The raw jitted ensemble call on a device-resident padded batch
        — the no-host-round-trip hot path (and what the transfer-guard
        test exercises).  ``obs`` is donated."""
        if self.set is None:
            raise ValueError("no ServingSet installed: call "
                             "server.install(serving_set) first")
        return self._infer(self._params, self._best, obs)

    def serve(self, obs) -> np.ndarray:
        """Answer a batch of observation requests.  ``obs`` is (B, obs_dim)
        (or a single (obs_dim,) request); B beyond ``max_batch`` is served
        in ``max_batch`` tiles, everything smaller is zero-padded up to the
        fixed shape so ONE executable serves every load level."""
        obs = np.asarray(obs, np.float32)
        single = obs.ndim == 1
        if single:
            obs = obs[None]
        t0 = time.perf_counter()
        outs = []
        tiles = 0
        for i in range(0, len(obs), self.max_batch):
            chunk = obs[i:i + self.max_batch]
            padded = np.zeros((self.max_batch,) + obs.shape[1:], np.float32)
            padded[:len(chunk)] = chunk
            acts = self.infer_device(self.place_request(padded))
            outs.append(np.asarray(acts)[:len(chunk)])
            tiles += 1
        self.requests_served += len(obs)
        if self._recording:
            # fill = real requests / padded slots dispatched: 1.0 means the
            # executable's fixed batch is earning its keep, low fill means
            # latency is being spent on zero padding
            self._window.add(time.perf_counter() - t0,
                             fill=len(obs) / (tiles * self.max_batch),
                             requests=len(obs))
            if (self.telemetry is not None
                    and self._window.count >= self.telemetry_every):
                self.report_telemetry()
        out = np.concatenate(outs, axis=0)
        return out[0] if single else out

    def report_telemetry(self):
        """Emit the current latency window as one ``serve`` row (p50/p99,
        fill ratio, queue depth) and start a fresh window.  Called
        automatically every ``telemetry_every`` batches; call it once more
        at shutdown for the partial tail."""
        if self.telemetry is None or not self._window.count:
            return
        self.telemetry.record(
            "serve", mode=self.mode, ensemble=getattr(self.set, "size", 0),
            max_batch=self.max_batch, **self._window.summary())
        self._window.reset()

    # ------------------------------------------------- request accumulation
    def submit(self, obs) -> int:
        """Enqueue one observation request; returns its slot in the next
        :meth:`flush`.  The queue refuses to grow past ``max_batch`` — at
        that point the caller flushes (a full batch IS the flush signal in
        a real frontend)."""
        if len(self._pending) >= self.max_batch:
            raise ValueError(f"request queue full ({self.max_batch}); "
                             f"flush() first")
        self._pending.append(np.asarray(obs, np.float32))
        self._window.observe_queue(len(self._pending))
        return len(self._pending) - 1

    def flush(self) -> np.ndarray:
        """Serve every queued request as one padded batch -> (queued, ...)
        actions in submission order."""
        if not self._pending:
            return np.zeros((0,))
        batch = np.stack(self._pending)
        self._pending = []
        return self.serve(batch)
