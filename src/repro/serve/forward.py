"""``PolicyForward`` — the ONE compiled deterministic policy forward.

Training-time evaluation and serving must agree bit-for-bit on what "the
policy's action" is, or the fitness that promotes a member into the serving
ensemble describes a different policy than the one traffic hits.  This
module pins that down as a tiny object both sides compose:

  * ``repro.rollout.Evaluator`` is env-stepping composed with
    ``PolicyForward.member`` (one member, one obs batch, inside its eval
    scan);
  * ``repro.serve.BatchServer`` is request batching composed with
    ``PolicyForward.members`` (every ensemble member on the same request
    batch, inside one jitted call).

The deterministic head is the ``key=None`` path of the exploration-policy
contract (``policy_fn(actor, obs, key, hypers)``): td3/sac take the mean
action, dqn goes greedy (epsilon never fires without a key), ppo returns
the distribution mode and its extras are dropped.  ``tests/test_serve.py``
asserts the serving forward reproduces the Evaluator's actions bitwise on
all four algorithms.
"""
from __future__ import annotations

import jax


class PolicyForward:
    """A deterministic action function over the exploration-policy contract.

    ``policy_fn(actor_params, obs, key, hypers) -> actions | (actions,
    extras)`` — the same callable the Collector/Evaluator drive; here it is
    always called with ``key=None, hypers=None`` (deterministic head,
    exploration off) and extras are discarded.
    """

    def __init__(self, policy_fn):
        self.policy_fn = policy_fn

    def member(self, actor, obs):
        """One member's deterministic actions on an observation batch."""
        out = self.policy_fn(actor, obs, None, None)
        # extras-emitting policies (ppo) return (actions, extras) even on
        # the deterministic path — same normalization as the Collector's
        # split_actions, inlined to keep this module import-cycle-free
        return out[0] if isinstance(out, tuple) else out

    def members(self, actors, obs):
        """Every member of a stacked param tree on the SAME observation
        batch -> actions with a leading member axis ``(M, B, ...)`` — the
        ensemble-inference shape ``BatchServer`` reduces over."""
        return jax.vmap(self.member, in_axes=(0, None))(actors, obs)

    @classmethod
    def for_agent(cls, agent) -> "PolicyForward":
        """The forward for a ``repro.pop`` agent: built from the same
        exploration module the rollout engine acts with, so serving and
        training share one policy definition, not two."""
        from repro.rollout.collector import default_exploration
        return cls(default_exploration(agent))
