"""``PolicyForward`` — the ONE compiled deterministic policy forward.

Training-time evaluation and serving must agree bit-for-bit on what "the
policy's action" is, or the fitness that promotes a member into the serving
ensemble describes a different policy than the one traffic hits.  This
module pins that down as a tiny object both sides compose:

  * ``repro.rollout.Evaluator`` is env-stepping composed with
    ``PolicyForward.member`` (one member, one obs batch, inside its eval
    scan);
  * ``repro.serve.BatchServer`` is request batching composed with
    ``PolicyForward.members`` (every ensemble member on the same request
    batch, inside one jitted call).

The deterministic head is the ``key=None`` path of the exploration-policy
contract (``policy_fn(actor, obs, key, hypers)``): td3/sac take the mean
action, dqn goes greedy (epsilon never fires without a key), ppo returns
the distribution mode and its extras are dropped.  ``tests/test_serve.py``
asserts the serving forward reproduces the Evaluator's actions bitwise on
all four algorithms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class PolicyForward:
    """A deterministic action function over the exploration-policy contract.

    ``policy_fn(actor_params, obs, key, hypers) -> actions | (actions,
    extras)`` — the same callable the Collector/Evaluator drive; here it is
    always called with ``key=None, hypers=None`` (deterministic head,
    exploration off) and extras are discarded.

    ``members_fn`` optionally replaces the default ``vmap``-of-``member``
    ensemble evaluation with a POPULATION-level forward
    ``members_fn(actors, obs) -> (M, B, ...)`` — the
    ``repro.rl.networks.pop_*_apply`` family, which routes its linears
    through ``kernels/pop_matmul`` on TPU (see :meth:`fused_for_agent`).
    The jnp fallback of those applies lowers to the same batched
    ``dot_general`` as the vmap, so switching it on never changes actions.
    """

    def __init__(self, policy_fn, members_fn=None):
        self.policy_fn = policy_fn
        self._members_fn = members_fn

    def member(self, actor, obs):
        """One member's deterministic actions on an observation batch."""
        out = self.policy_fn(actor, obs, None, None)
        # extras-emitting policies (ppo) return (actions, extras) even on
        # the deterministic path — same normalization as the Collector's
        # split_actions, inlined to keep this module import-cycle-free
        return out[0] if isinstance(out, tuple) else out

    def members(self, actors, obs):
        """Every member of a stacked param tree on the SAME observation
        batch -> actions with a leading member axis ``(M, B, ...)`` — the
        ensemble-inference shape ``BatchServer`` reduces over."""
        if self._members_fn is not None:
            return self._members_fn(actors, obs)
        return jax.vmap(self.member, in_axes=(0, None))(actors, obs)

    @classmethod
    def for_agent(cls, agent) -> "PolicyForward":
        """The forward for a ``repro.pop`` agent: built from the same
        exploration module the rollout engine acts with, so serving and
        training share one policy definition, not two."""
        from repro.rollout.collector import default_exploration
        return cls(default_exploration(agent))

    @classmethod
    def fused_for_agent(cls, agent, *, fused=None) -> "PolicyForward":
        """Like :meth:`for_agent`, but the ensemble call evaluates every
        member through ONE population-batched forward
        (``repro.rl.networks.pop_*_apply``, the ``kernels/pop_matmul``
        layout) instead of ``vmap`` over per-member applies.  Single-member
        evaluation (:meth:`member`) is untouched, so the Evaluator parity
        contract of ``tests/test_serve.py`` holds by construction.

        ``fused`` is the per-linear routing knob of the pop applies (None =
        kernel on TPU where tileable, True = force/interpret, False = jnp).
        Falls back to the default forward for agents without a recognized
        deterministic head (e.g. the Atari conv torso).
        """
        from repro.rl import networks as nets

        name = getattr(agent.module, "__name__", "").rsplit(".", 1)[-1]

        def broadcast(obs, actors):
            m = jax.tree.leaves(actors)[0].shape[0]
            return jnp.broadcast_to(obs[None], (m,) + obs.shape)

        if name == "td3":
            def members_fn(actors, obs):
                return nets.pop_actor_apply(actors, broadcast(obs, actors),
                                            fused=fused)
        elif name == "sac":
            def members_fn(actors, obs):
                mean, _ = nets.pop_gaussian_actor_apply(
                    actors, broadcast(obs, actors), fused=fused)
                return jnp.tanh(mean)
        elif name == "dqn":
            def members_fn(actors, obs):
                q = nets.pop_q_net_apply(actors, broadcast(obs, actors),
                                         fused=fused)
                return jnp.argmax(q, axis=-1)
        elif name == "ppo":
            def members_fn(actors, obs):
                obs_b = broadcast(obs, actors["actor"])
                if "log_std" in actors:   # continuous: the tanh mean
                    return nets.pop_actor_apply(actors["actor"], obs_b,
                                                fused=fused)
                logits = nets.pop_mlp_apply(actors["actor"], obs_b,
                                            fused=fused)
                return jnp.argmax(logits, axis=-1)
        else:
            return cls.for_agent(agent)

        fwd = cls.for_agent(agent)
        fwd._members_fn = members_fn
        return fwd
