"""Fault-tolerant checkpointing: atomic, async, retention, auto-resume.

1000-node design notes:
  * writes are atomic (tmp dir + ``os.replace``) — a preempted writer never
    corrupts the latest checkpoint, so any surviving worker can restart from
    ``latest()``;
  * saves can run on a background thread (``save_async``) so the train loop
    never blocks on IO (straggler mitigation at the host level);
  * a retention policy bounds disk usage;
  * ``SignalHandler`` flushes an emergency checkpoint on SIGTERM (the
    preemption signal on cloud TPU/TRN fleets).
  * on real multi-host meshes each host writes only the shards it owns
    (addressable shards); on this single-host runtime that degenerates to a
    full write, same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _dump_tree(directory: Path, name: str, tree: Any):
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(jax.device_get(l))
            for i, l in enumerate(leaves)}
    np.savez(directory / f"{name}.npz", **arrs)
    return len(leaves), treedef


def save_pytree(path: str | Path, tree: Any, extra: dict | None = None,
                aux: dict[str, Any] | None = None):
    """Atomic save: write to <path>.tmp then os.replace.

    ``aux`` is a dict of independently-restorable side trees (e.g. a
    rollout engine's replay buffers) saved alongside the main tree in the
    same atomic rename: a reader either sees the whole checkpoint or none
    of it.  Aux trees restore via :func:`load_aux` with their own template,
    so a consumer that lacks the producer's side state (a trainer without
    an attached rollout) can still restore the main tree.
    """
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    num, treedef = _dump_tree(tmp, "arrays", tree)
    aux_meta = {name: _dump_tree(tmp, f"aux_{name}", t)[0]
                for name, t in (aux or {}).items()}
    meta = {"num_leaves": num, "extra": extra or {},
            "treedef": str(treedef), "aux": aux_meta}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)


def _load_tree(file: Path, template: Any):
    with np.load(file) as data:
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    _, treedef = _flatten(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"{file} holds {len(leaves)} leaves but the restore template "
            f"has {treedef.num_leaves}: the checkpoint was written with a "
            f"different structure (an older format, or a different "
            f"strategy/hyper space) — restore with a matching template or "
            f"start fresh")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_pytree(path: str | Path, template: Any):
    """Restore into the structure of ``template`` (dtypes preserved; shapes
    come from the saved arrays, which is what makes elastic re-layout
    possible — a template of a different population size still restores)."""
    return _load_tree(Path(path) / "arrays.npz", template)


def load_aux(path: str | Path, name: str, template: Any):
    """Restore the named aux tree, or None when this checkpoint has none
    (e.g. it was written before the producer gained that side state)."""
    file = Path(path) / f"aux_{name}.npz"
    if not file.exists():
        return None
    return _load_tree(file, template)


def load_extra(path: str | Path) -> dict:
    return json.loads((Path(path) / "meta.json").read_text())["extra"]


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 run_meta: dict | None = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # run-metadata header: merged into every checkpoint's JSON extras
        # under "run" (run_id, log path, ...) so a checkpoint can be joined
        # back to the telemetry stream that recorded its training — set at
        # construction or later (PopTrainer stamps its RunTelemetry id)
        self.run_meta = dict(run_meta) if run_meta else None
        self._thread: threading.Thread | None = None

    def _ckpt_path(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp"):
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(steps)

    def latest(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, extra: dict | None = None,
             aux: dict[str, Any] | None = None):
        extra = dict(extra or {}, step=step)
        if self.run_meta is not None:
            extra.setdefault("run", self.run_meta)
        save_pytree(self._ckpt_path(step), tree, extra, aux=aux)
        self._gc()

    def save_async(self, step: int, tree: Any, extra: dict | None = None,
                   aux: dict[str, Any] | None = None):
        """Non-blocking save; device->host copy happens here (cheap), IO on
        the background thread."""
        self.wait()
        host_tree = jax.device_get(tree)
        host_aux = None if aux is None else jax.device_get(aux)
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree, extra, host_aux),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template: Any, step: int | None = None):
        step = self.latest() if step is None else step
        if step is None:
            return None, None
        path = self._ckpt_path(step)
        return load_pytree(path, template), load_extra(path)

    def restore_aux(self, name: str, template: Any,
                    step: int | None = None):
        """Restore a named aux tree (see ``save_pytree``), or None when the
        checkpoint predates it / the producer had none."""
        step = self.latest() if step is None else step
        if step is None:
            return None
        return load_aux(self._ckpt_path(step), name, template)

    def peek_extra(self, step: int | None = None,
                   require: tuple = ("step", "size", "fitness")) -> dict | None:
        """The JSON extras of a checkpoint WITHOUT loading any arrays —
        cheap enough for a launcher deciding how to re-layout, or a serving
        watcher deciding whether to promote, before anything is built
        (``repro.elastic`` reads size/fitness here; ``repro.serve`` reads
        all three).

        Returns None when the directory holds no checkpoint.  A checkpoint
        that exists but lacks a required key raises instead of returning a
        partially-populated dict: the old behaviour let a pre-size/fitness
        checkpoint (written before PopTrainer.save recorded them) flow into
        ``meta.get(...)`` call sites and silently disable elastic resize
        and fitness-ranked promotion.  ``fitness`` may legitimately be
        recorded as None (a save right after an evolve) — required means
        the key is PRESENT, not non-null.  Pass ``require=()`` to read raw
        extras from checkpoints this trainer didn't write."""
        step = self.latest() if step is None else step
        if step is None:
            return None
        extra = load_extra(self._ckpt_path(step))
        missing = [k for k in require if k not in extra]
        if missing:
            raise KeyError(
                f"checkpoint {self._ckpt_path(step)} lacks extras "
                f"{missing} (has {sorted(extra)}): it predates the "
                f"size/fitness metadata PopTrainer.save records — resume "
                f"it with the run that wrote it and re-save, or read raw "
                f"extras with peek_extra(require=())")
        return extra

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._ckpt_path(s), ignore_errors=True)


class SignalHandler:
    """SIGTERM/SIGINT → emergency checkpoint before exit (preemption)."""

    def __init__(self, manager: CheckpointManager, get_state):
        self.manager = manager
        self.get_state = get_state
        self.triggered = False
        for sig in (signal.SIGTERM,):
            try:
                signal.signal(sig, self._handle)
            except ValueError:  # not main thread (tests)
                pass

    def _handle(self, signum, frame):
        self.triggered = True
        step, tree, extra = self.get_state()
        self.manager.wait()
        self.manager.save(step, tree, dict(extra, preempted=True))
