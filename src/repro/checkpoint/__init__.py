from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, load_aux, load_pytree, save_pytree,
)
