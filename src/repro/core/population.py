"""Population state = stacked pytrees (the paper's core data layout).

A population of N agents is the single-agent state pytree with a leading
population axis on every leaf.  This is what makes the paper's protocol
work: one ``vmap`` over axis 0 turns the single-agent update step into the
population update step, memory is allocated in one chunk per leaf
(minimizing fragmentation — §4 "Memory considerations"), and the same pytree
shards over a mesh axis for multi-accelerator populations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def population_init(init_fn, key, n: int):
    """vmap an ``init_fn(key) -> state`` over n split keys."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def stack_members(members):
    """List of per-member pytrees -> stacked population pytree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *members)


def unstack_members(pop):
    n = population_size(pop)
    return [jax.tree.map(lambda x: x[i], pop) for i in range(n)]


def member(pop, i):
    return jax.tree.map(lambda x: x[i], pop)


def population_size(pop) -> int:
    return jax.tree.leaves(pop)[0].shape[0]
