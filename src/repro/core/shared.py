"""Shared-critic population update — the paper's §4.2 contribution.

CEM-RL / DvD / QD-PG share ONE critic across the population while each
member owns its policy.  The original CEM-RL interleaves per-member critic
updates sequentially, which kills vectorization.  The paper's second-order
modification: every batch flows through ALL policies in parallel and the
critic loss is AVERAGED over the population (same total number of critic
updates; no impact on sample efficiency — paper Figs. 6/8).

This module implements that update for TD3 (the algorithm all three case
studies use):
  * critic step: mean over members of the per-member TD3 critic loss,
    gradients flowing into the single shared critic;
  * policy step: per-member TD3 actor loss against the shared critic,
    vmapped (optionally + a joint DvD diversity term).
"""
from __future__ import annotations

from typing import NamedTuple, Any

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates
from repro.rl import networks as nets
from repro.rl.td3 import NOISE_CLIP, TAU, DEFAULT_HYPERS
from repro.core.dvd import behavior_embedding, dvd_loss

_opt_init, _opt_update = adam(3e-4)


class SharedCriticState(NamedTuple):
    policies: Any          # stacked (N, ...) actor params
    critic: Any            # single shared critic
    target_policies: Any
    target_critic: Any
    policy_opt: Any        # stacked
    critic_opt: Any
    step: jnp.ndarray
    key: jnp.ndarray


def init(key, obs_dim: int, act_dim: int, n: int) -> SharedCriticState:
    kp, kc, kk = jax.random.split(key, 3)
    policies = jax.vmap(lambda k: nets.actor_init(k, obs_dim, act_dim))(
        jax.random.split(kp, n))
    critic = nets.critic_init(kc, obs_dim, act_dim)
    return SharedCriticState(
        policies=policies, critic=critic,
        target_policies=jax.tree.map(jnp.copy, policies),
        target_critic=jax.tree.map(jnp.copy, critic),
        policy_opt=jax.vmap(_opt_init)(policies), critic_opt=_opt_init(critic),
        step=jnp.zeros((), jnp.int32), key=kk)


def _member_critic_loss(critic, target_policy, target_critic, batch, key, h):
    noise = jnp.clip(h["noise"] * jax.random.normal(key, batch["action"].shape),
                     -NOISE_CLIP, NOISE_CLIP)
    next_a = jnp.clip(nets.actor_apply(target_policy, batch["next_obs"]) + noise,
                      -1.0, 1.0)
    tq1, tq2 = nets.critic_apply(target_critic, batch["next_obs"], next_a)
    target = batch["reward"] + h["discount"] * (1 - batch["done"]) * \
        jnp.minimum(tq1, tq2)
    q1, q2 = nets.critic_apply(critic, batch["obs"], batch["action"])
    target = jax.lax.stop_gradient(target)
    return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2)


def make_shared_critic_update(*, dvd_coef_fn=None, probe_size: int = 20,
                              train_frac: float = 1.0,
                              fused_adam: bool = False,
                              fused_linear: bool = False):
    """Returns jit-able ``update(state, batches, hypers) -> (state, metrics)``.

    batches: pytree with leading (N, B, ...) — one batch per member (§4.2:
    "each batch of training data goes through all of the policy networks").

    ``train_frac < 1`` trains only the first ``round(N * train_frac)``
    members (CEM-RL trains half the sampled policies, Algorithm 1): the
    critic loss averages over the trainees and the remaining members'
    policies/optimizers are left untouched.

    ``fused_adam`` routes the per-member policy Adam step — the one
    population-level optimizer application in the repo — through
    ``repro.optim.population_adam`` (the ``kernels/pop_adam`` Pallas path
    on TPU, a numerically identical jnp fallback elsewhere) instead of
    ``vmap`` over the stock optimizer.  Same ``AdamState`` structure either
    way, so checkpoints don't care.

    ``fused_linear`` additionally evaluates the member POLICY forwards
    (the target-policy next-action in the critic loss, the actor loss)
    through the population-batched ``repro.rl.networks.pop_actor_apply``
    (the ``kernels/pop_matmul`` path on TPU) instead of ``vmap`` of the
    per-member apply.  The shared critic itself has no population axis and
    stays on the plain apply.
    """
    if fused_adam:
        from repro.optim.pop_adam import population_adam
        _, _pop_apply = population_adam(3e-4)

    def update(state: SharedCriticState, batches, hypers=None):
        h = dict(DEFAULT_HYPERS)
        if hypers:
            h.update(hypers)
        key, kc = jax.random.split(state.key)
        n = jax.tree.leaves(batches)[0].shape[0]
        k_train = max(1, round(n * train_frac))
        trained = jnp.arange(n) < k_train   # (N,) static-shape gate

        # --- critic step: loss averaged over the trainees (§4.2) -----------
        if fused_linear:
            def critic_loss(critic):
                keys = jax.random.split(kc, n)
                eps = jax.vmap(lambda k: jax.random.normal(
                    k, batches["action"].shape[1:]))(keys)
                noise = jnp.clip(h["noise"] * eps, -NOISE_CLIP, NOISE_CLIP)
                next_a = jnp.clip(
                    nets.pop_actor_apply(state.target_policies,
                                         batches["next_obs"]) + noise,
                    -1.0, 1.0)
                tq1, tq2 = nets.critic_apply(state.target_critic,
                                             batches["next_obs"], next_a)
                target = batches["reward"] + h["discount"] * \
                    (1 - batches["done"]) * jnp.minimum(tq1, tq2)
                q1, q2 = nets.critic_apply(critic, batches["obs"],
                                           batches["action"])
                target = jax.lax.stop_gradient(target)
                losses = jnp.mean((q1 - target) ** 2, axis=1) + \
                    jnp.mean((q2 - target) ** 2, axis=1)
                return jnp.sum(jnp.where(trained, losses, 0.0)) / k_train
        else:
            def critic_loss(critic):
                keys = jax.random.split(kc, n)
                losses = jax.vmap(
                    lambda tp, b, k: _member_critic_loss(
                        critic, tp, state.target_critic, b, k, h)
                )(state.target_policies, batches, keys)
                return jnp.sum(jnp.where(trained, losses, 0.0)) / k_train

        closs, cgrads = jax.value_and_grad(critic_loss)(state.critic)
        cupd, critic_opt = _opt_update(cgrads, state.critic_opt,
                                       lr_override=h["critic_lr"])
        critic = apply_updates(state.critic, cupd)

        # --- policy step: per-member actor loss, vmapped -------------------
        def pop_actor_loss(policies):
            if fused_linear:
                a = nets.pop_actor_apply(policies, batches["obs"])
                q1, _ = nets.critic_apply(critic, batches["obs"], a)
                loss = jnp.mean(-jnp.mean(q1, axis=1))
            else:
                def one(policy, b):
                    a = nets.actor_apply(policy, b["obs"])
                    q1, _ = nets.critic_apply(critic, b["obs"], a)
                    return -jnp.mean(q1)
                loss = jnp.mean(jax.vmap(one)(policies, batches))
            if dvd_coef_fn is not None:
                probe = jax.tree.map(lambda x: x[0, :probe_size],
                                     batches)["obs"]
                emb = behavior_embedding(nets.actor_apply, policies, probe)
                loss = loss + dvd_coef_fn(state.step) * dvd_loss(emb)
            return loss

        aloss, agrads = jax.value_and_grad(pop_actor_loss)(state.policies)
        if fused_adam:
            policies_new, policy_opt_new = _pop_apply(
                state.policies, agrads, state.policy_opt,
                lr_override=h["actor_lr"])
        else:
            aupd, policy_opt_new = jax.vmap(
                lambda g, o: _opt_update(g, o, lr_override=h["actor_lr"])
            )(agrads, state.policy_opt)
            policies_new = apply_updates(state.policies, aupd)
        # non-trainees keep their params/optimizer bit-identical
        gate = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(
                trained.reshape((n,) + (1,) * (a.ndim - 1)), a, b), new, old)
        policies = gate(policies_new, state.policies)
        policy_opt = gate(policy_opt_new, state.policy_opt)

        soft = lambda t, o: jax.tree.map(
            lambda a, b: (1 - TAU) * a + TAU * b, t, o)
        new_state = SharedCriticState(
            policies=policies, critic=critic,
            target_policies=gate(soft(state.target_policies, policies),
                                 state.target_policies),
            target_critic=soft(state.target_critic, critic),
            policy_opt=policy_opt, critic_opt=critic_opt,
            step=state.step + 1, key=key)
        return new_state, {"critic_loss": closs, "actor_loss": aloss}

    return update


def sequential_shared_critic_update():
    """The ORIGINAL CEM-RL ordering (Algorithm 1): per-member critic updates
    interleaved sequentially between policy updates.  Kept as the baseline
    arm for the paper's Fig. 4 benchmark."""

    def update(state: SharedCriticState, batches, hypers=None):
        h = dict(DEFAULT_HYPERS)
        if hypers:
            h.update(hypers)
        key, kc = jax.random.split(state.key)
        n = jax.tree.leaves(batches)[0].shape[0]
        critic, critic_opt = state.critic, state.critic_opt
        closs = jnp.zeros(())
        for i in range(n):
            b = jax.tree.map(lambda x: x[i], batches)
            tp = jax.tree.map(lambda x: x[i], state.target_policies)
            li, g = jax.value_and_grad(_member_critic_loss)(
                critic, tp, state.target_critic, b,
                jax.random.fold_in(kc, i), h)
            u, critic_opt = _opt_update(g, critic_opt,
                                        lr_override=h["critic_lr"])
            critic = apply_updates(critic, u)
            closs = closs + li / n

        def one_actor(policy, opt, b):
            def loss(p):
                a = nets.actor_apply(p, b["obs"])
                q1, _ = nets.critic_apply(critic, b["obs"], a)
                return -jnp.mean(q1)
            l, g = jax.value_and_grad(loss)(policy)
            u, opt = _opt_update(g, opt, lr_override=h["actor_lr"])
            return apply_updates(policy, u), opt, l

        policies, policy_opt, alosses = jax.vmap(one_actor)(
            state.policies, state.policy_opt, batches)
        soft = lambda t, o: jax.tree.map(
            lambda a, b: (1 - TAU) * a + TAU * b, t, o)
        new_state = SharedCriticState(
            policies=policies, critic=critic,
            target_policies=soft(state.target_policies, policies),
            target_critic=soft(state.target_critic, critic),
            policy_opt=policy_opt, critic_opt=critic_opt,
            step=state.step + 1, key=key)
        return new_state, {"critic_loss": closs,
                           "actor_loss": jnp.mean(alosses)}

    return update
