"""Cross-Entropy Method over policy parameters (CEM-RL, Pourchot & Sigaud).

The CEM distribution is a diagonal gaussian over the *flattened* policy
parameter vector.  Sampling N members = one (N, P) matrix — which is exactly
the stacked-population layout, so CEM composes with the vectorized TD3
update for the CEM-RL case study (§5.2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class CEMState(NamedTuple):
    mean: jnp.ndarray      # (P,)
    var: jnp.ndarray       # (P,)
    noise: jnp.ndarray     # scalar additive noise (decays)


def cem_init(params_template, sigma_init: float = 1e-2,
             noise_init: float = 1e-2):
    """The paper increases CEM initial noise from 1e-3 to 1e-2 (§B.2)."""
    flat, unravel = ravel_pytree(params_template)
    state = CEMState(mean=flat, var=jnp.full_like(flat, sigma_init),
                     noise=jnp.asarray(noise_init))
    return state, unravel


def cem_sample(key, state: CEMState, n: int):
    eps = jax.random.normal(key, (n,) + state.mean.shape)
    return state.mean + jnp.sqrt(state.var + state.noise) * eps


def cem_update(state: CEMState, samples, fitness, elite_frac: float = 0.5,
               noise_decay: float = 0.999):
    """samples: (N, P); fitness: (N,) higher-better. Elite-weighted update."""
    n = fitness.shape[0]
    k = max(1, int(round(n * elite_frac)))
    elite_idx = jnp.argsort(fitness)[n - k:]
    elites = samples[elite_idx]
    # log-rank weights (standard CEM-RL weighting)
    w = jnp.log(1 + k) - jnp.log(jnp.arange(1, k + 1, dtype=jnp.float32))
    w = (w / w.sum())[::-1]                   # ascending fitness order
    mean = jnp.einsum("i,ip->p", w, elites)
    var = jnp.einsum("i,ip->p", w, jnp.square(elites - state.mean))
    return CEMState(mean=mean, var=var, noise=state.noise * noise_decay)
