"""GSPMD distribution of the population over the device mesh.

This is the IMPLICIT multi-device path (``backend="sharded"``): the
population axis of every stacked pytree is sharded over mesh axes via
``NamedSharding`` and XLA's partitioner decides the rest; the PBT exploit
step — a gather by parent index — lowers to XLA collectives automatically
under jit, so cross-pod member exchange costs one collective per PBT
interval.  The EXPLICIT path — the paper's §5.1 islands topology
(80 agents = 4 T4s x 20 vectorized members) as a literal shard_map over
member groups — is ``repro.elastic`` and ``backend="islands"``; see
docs/scaling.md for when to pick which.

``population_sharding`` builds NamedShardings that put the population axis
on the requested mesh axes and replicate everything else (each member's
parameters are small, per the paper's §3 assumption; large-model members
use the FSDP/TP specs of repro.models.sharding instead).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def population_axes(mesh) -> tuple:
    names = tuple(mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in names)


def population_sharding(tree, mesh, n: int | None = None):
    """Shard leading population axis over ('pod','data'); replicate rest."""
    axes = population_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def spec(leaf):
        pop = jax.tree.leaves(tree)[0].shape[0] if n is None else n
        if leaf.ndim >= 1 and leaf.shape[0] == pop and size > 1 and pop % size == 0:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())
    return jax.tree.map(spec, tree)


def shard_population(tree, mesh):
    return jax.device_put(tree, population_sharding(tree, mesh))


def all_members_fitness(fitness, mesh):
    """Fitness is tiny ((N,)); keep it replicated so the argsort in pbt_step
    is local on every device (one all-gather, inserted by XLA)."""
    return jax.device_put(fitness, NamedSharding(mesh, P()))
