"""On-device PBT exploit/explore (paper §5.1, Jaderberg et al. 2017).

Everything is ``jax.lax`` — no host round-trip — so the PBT step jit-compiles
and, when the population axis is sharded over the mesh (pod axis), the member
gathers lower to XLA collectives (see core/distributed.py).  Protocol
(paper §B.1): every ``pbt_interval`` update steps, the bottom
``exploit_frac`` of members (by windowed fitness) copy the full training
state of a random top-``exploit_frac`` member and re-explore hyperparameters.

Straggler note: fitness enters as "last known" values — a member whose
actors lag simply keeps its previous window (late fitness reports do not
block the step), which is the paper's async-friendly behaviour.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import PopulationConfig
from repro.core.hyperparams import perturb_hypers


def pbt_step(key, pop_state, hypers, fitness, pcfg: PopulationConfig,
             gather=None):
    """fitness: (N,) — higher is better. Returns (pop_state, hypers, parents).

    ``parents[i]`` is the member whose state member i now holds (== i for
    survivors); exposed for logging/lineage tracking.  ``gather(pop_state,
    parents)`` overrides the member copy for states that are not plain
    stacked pytrees (e.g. the shared-critic family, where only the
    per-member components move).
    """
    n = fitness.shape[0]
    k = max(1, int(round(n * pcfg.exploit_frac)))
    order = jnp.argsort(fitness)              # ascending
    bottom, top = order[:k], order[n - k:]

    kp, kh = jax.random.split(key)
    parent_choice = top[jax.random.randint(kp, (k,), 0, k)]
    parents = jnp.arange(n).at[bottom].set(parent_choice)

    if gather is None:
        new_state = jax.tree.map(lambda x: x[parents], pop_state)
    else:
        new_state = gather(pop_state, parents)
    replaced = jnp.zeros((n,), bool).at[bottom].set(True)
    new_hypers = jax.tree.map(lambda x: x[parents], hypers)
    new_hypers = perturb_hypers(kh, new_hypers, pcfg.hyper_space, replaced,
                                perturb_prob=pcfg.perturb_prob,
                                scale=pcfg.perturb_scale)
    return new_state, new_hypers, parents
