"""DvD diversity loss (Parker-Holder et al., 2020) — §5.3.

Diversity of a population is the volume (determinant) of the RBF kernel
matrix of *behavioral embeddings* — each policy's concatenated actions on a
shared probe-state batch.  Because all policy parameters live in one stacked
pytree, the joint term is a single vmap + logdet; gradients flow to every
member in one backward pass (the property the paper calls "trivial to
implement with JAX building upon the CEM-RL one").

The diversity coefficient uses a schedule (paper §B.2 replaces the original
bandit with a schedule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def behavior_embedding(policy_apply, pop_params, probe_obs):
    """Embed each member: actions on probe states, flattened. -> (N, E)."""
    def one(params):
        return policy_apply(params, probe_obs).reshape(-1)
    return jax.vmap(one)(pop_params)


def rbf_kernel(embeddings, *, length_scale: float = 1.0, eps: float = 1e-4):
    """The RBF kernel matrix of member embeddings (N, N) whose determinant
    IS the DvD diversity measure — shared by the training-time loss below
    and the serving-set selection in ``repro.serve.ensemble``, so "diverse"
    means the same thing on both sides."""
    d2 = jnp.sum(
        jnp.square(embeddings[:, None, :] - embeddings[None, :, :]), axis=-1)
    n = embeddings.shape[0]
    k = jnp.exp(-d2 / (2 * length_scale ** 2 * embeddings.shape[-1]))
    return k + eps * jnp.eye(n)


def dvd_loss(embeddings, *, length_scale: float = 1.0, eps: float = 1e-4):
    """-log det of the RBF kernel matrix of member embeddings (maximize
    diversity == minimize this loss)."""
    k = rbf_kernel(embeddings, length_scale=length_scale, eps=eps)
    sign, logdet = jnp.linalg.slogdet(k)
    return -logdet


def dvd_coef_schedule(step, period: int = 20_000, hi: float = 0.5,
                      lo: float = 0.0):
    """Square-wave schedule for the diversity coefficient (§B.2)."""
    phase = (step // (period // 2)) % 2
    return jnp.where(phase == 0, lo, hi)
