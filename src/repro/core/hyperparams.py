"""Per-member hyperparameters as vmapped leaves (paper §5.1 / §B.1).

Hyperparameters live in a dict of (N,)-shaped arrays and are passed to the
vmapped update step like any other input, so each member trains with its own
values inside ONE compiled call.  Sampling follows the paper's priors:
log-uniform for learning rates, uniform for the rest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import HyperSpace


def sample_hypers(key, space: HyperSpace, n: int):
    out = {}
    for i, (name, lo, hi) in enumerate(space.log_uniform):
        k = jax.random.fold_in(key, i)
        out[name] = jnp.exp(jax.random.uniform(
            k, (n,), minval=jnp.log(lo), maxval=jnp.log(hi)))
    for j, (name, lo, hi) in enumerate(space.uniform):
        k = jax.random.fold_in(key, 1000 + j)
        out[name] = jax.random.uniform(k, (n,), minval=lo, maxval=hi)
    return out


def _bounds(space: HyperSpace, name: str):
    for n, lo, hi in tuple(space.log_uniform) + tuple(space.uniform):
        if n == name:
            return lo, hi
    raise KeyError(name)


def perturb_hypers(key, hypers, space: HyperSpace, mask,
                   perturb_prob: float = 0.5, scale: float = 1.2):
    """PBT explore: for members where ``mask`` is True, either resample from
    the prior or multiply by scale^{±1} (clipped to the prior range)."""
    fresh = sample_hypers(jax.random.fold_in(key, 0), space,
                          mask.shape[0])
    out = {}
    for i, name in enumerate(sorted(hypers)):
        lo, hi = _bounds(space, name)
        k1, k2 = jax.random.split(jax.random.fold_in(key, 17 + i))
        up = jax.random.bernoulli(k1, 0.5, mask.shape)
        perturbed = jnp.clip(hypers[name] * jnp.where(up, scale, 1.0 / scale),
                             lo, hi)
        use_resample = jax.random.bernoulli(k2, perturb_prob, mask.shape)
        explored = jnp.where(use_resample, fresh[name], perturbed)
        out[name] = jnp.where(mask, explored, hypers[name])
    return out
