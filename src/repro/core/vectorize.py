"""The paper's vectorization + compilation protocols (§4.1).

Given a single-agent ``update_fn(state, batch, hypers) -> (state, metrics)``:

  * ``vectorized_update``  — *Jax (Vectorized)*: ``jit(vmap(update))``; one
    batched kernel launch updates the whole population.
  * ``chain_steps``        — the "num_steps" protocol: JIT ``k`` update steps
    into one call so parameters never round-trip to host memory between
    steps (the paper chains 50 for TD3/SAC, 10 for DQN).
  * ``sequential_update``  — *Jax (Sequential)*: the baseline loop the paper
    compares against (one jit'd per-member call, applied member by member).

All three take/return the stacked population pytree of
``repro.core.population`` so they are drop-in interchangeable — the
benchmark harness measures them against each other (paper Fig. 2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.population import member, population_size, stack_members


def chain_steps(update_fn, num_steps: int):
    """update over a (num_steps, ...) batch stack via lax.scan.

    Float metrics are MEANED over the chained window (a k-sample fitness
    estimate for PBT, not the last step's 1-sample one); integer metrics
    (step counters) keep the final value.
    """
    def chained(state, batches, hypers=None):
        def body(s, b):
            s, m = update_fn(s, b, hypers)
            return s, m
        state, metrics = jax.lax.scan(body, state, batches)
        return state, jax.tree.map(
            lambda x: jnp.mean(x, axis=0)
            if jnp.issubdtype(x.dtype, jnp.floating) else x[-1], metrics)
    return chained


def vectorized_update(update_fn, num_steps: int = 1, donate: bool = True):
    """The paper's protocol: jit(vmap(chain(update))).

    Returns ``fn(pop_state, batches, hypers)`` where
      pop_state: stacked population pytree (leading N),
      batches:   leaves (N, ...) if num_steps == 1 else (num_steps, N, ...),
      hypers:    dict of (N,) arrays or None.
    Buffer donation makes the population update in-place on device.
    """
    inner = update_fn if num_steps == 1 else chain_steps(update_fn, num_steps)
    in_axes = (0, 0 if num_steps == 1 else 1, 0)

    def stepped(pop_state, batches, hypers=None):
        if hypers is None:
            return jax.vmap(lambda s, b: inner(s, b, None),
                            in_axes=in_axes[:2])(pop_state, batches)
        return jax.vmap(inner, in_axes=in_axes)(pop_state, batches, hypers)

    return jax.jit(stepped, donate_argnums=(0,) if donate else ())


def sequential_update(update_fn, num_steps: int = 1):
    """The paper's *Jax (Sequential)* baseline: one jit'd single-agent call,
    applied to each member in a python loop (graph compiled once)."""
    inner = update_fn if num_steps == 1 else chain_steps(update_fn, num_steps)
    inner = jax.jit(inner)

    def stepped(pop_state, batches, hypers=None):
        n = population_size(pop_state)
        outs = []
        for i in range(n):
            b = jax.tree.map(lambda x: x[i] if num_steps == 1 else x[:, i],
                             batches)
            h = None if hypers is None else jax.tree.map(lambda x: x[i], hypers)
            outs.append(inner(member(pop_state, i), b, h))
        states = stack_members([o[0] for o in outs])
        metrics = stack_members([o[1] for o in outs])
        return states, metrics

    return stepped
