# The paper's primary contribution: vectorization + compilation protocols
# for population-based training (FastPBRL, ICML 2022).
# These are the low-level building blocks; the unified training API that
# composes them (Agent / EvolutionStrategy / UpdateBackend / PopTrainer)
# lives in repro.pop.
from repro.core.population import (  # noqa: F401
    population_init, stack_members, unstack_members, member, population_size,
)
from repro.core.vectorize import (  # noqa: F401
    vectorized_update, sequential_update, chain_steps,
)
from repro.core.hyperparams import sample_hypers, perturb_hypers  # noqa: F401
from repro.core.pbt import pbt_step  # noqa: F401
from repro.core.cem import cem_init, cem_sample, cem_update  # noqa: F401
from repro.core.dvd import dvd_loss, behavior_embedding  # noqa: F401
from repro.core.shared import make_shared_critic_update  # noqa: F401
