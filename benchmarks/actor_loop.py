"""Acting-engine benchmark: fused vs unfused train iteration (paper §4).

The paper's central claim is that population training costs ~one agent only
when BOTH phases — acting and updating — are compiled and vectorized over
the population.  This harness measures one full train iteration two ways
for BOTH experience kinds of the pipeline:

  td3 (off-policy, replay): collect ``collect_steps`` × ``num_envs`` env
      steps per member -> insert -> sample -> ``num_updates`` chained
      updates.
  ppo (on-policy, trajectory): collect (recording log_prob/value extras)
      -> on-device GAE -> ``epochs`` × shuffled minibatch updates.

  fused    — ``repro.rollout`` engine: ONE jitted call, everything stays on
             device (``PopTrainer.env_iteration``).  The fused arm also
             records ``single_jit``: whether a post-warmup iteration runs
             clean under ``jax.transfer_guard("disallow")`` — the
             no-host-round-trip property the engine promises.
  unfused  — the pre-engine loop shape: separately-jitted phases with a
             host sync between each, which is what hand-rolled loops pay
             every iteration.

The default shape follows the paper's acting setup — ONE env per member,
many acting steps per iteration, a short chained update — because that is
the regime where the fused/unfused and population-overhead questions are
about the *loop*, not about raw matmul throughput (this box has 2 CPU
cores, so a compute-bound update trivially scales linearly and would bury
the acting-side signal the paper is about).

Reported per (algo, population size): ms per iteration, env interactions
per second, iteration time relative to population 1 (the paper's
minimal-overhead claim), and the fused-over-unfused speedup.
``--json PATH`` additionally dumps the rows as JSON for trend tracking
(same row schema for both algos).

``--num-envs N[,N...]`` switches to the OVERLAP sweep instead: serial
fused vs the pipelined ``policy_lag=1`` engine on the physics env
(``hopper2d``) at GPU-sim env counts.  Each cell runs a K-iteration PBT
driver loop — every iteration ends with the host fitness read every
PBT/CEM driver performs — because that read is exactly the sync the
overlapped engine hides: the serial program must finish collect+update
before the stats materialize, while the overlapped engine hands back the
previous slot's stats immediately and keeps the device busy underneath
the host's bookkeeping and dispatch.  Rows land in the same
``kind="bench"`` JSONL schema, with steady-state recompiles counted via
``repro.compat.register_compile_listener`` (must be 0).
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_rows
from repro import compat
from repro.configs.base import PopulationConfig
from repro.data import buffer_add, buffer_sample
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer, PPOAgent, make_update
from repro.rl import td3

HIDDEN = (32, 32)   # small nets leave the 2 CPU cores idle capacity, the
                    # accelerator regime the paper's scaling claim assumes;
                    # 256-256 MLPs saturate this box at pop 2 and every arm
                    # degenerates to linear compute scaling


def _timed_rounds(cells, iters: int = 10, warmup: int = 2):
    """Time every cell round-robin and keep each cell's minimum.

    Interleaving + min is deliberate: this box is time-shared and stolen-CPU
    noise comes in phases that last longer than one arm's measurement, so
    timing the arms back-to-back makes them incomparable.  One round times
    every (algo, pop, impl) cell once; the per-cell minimum over all rounds
    samples every machine phase for every cell."""
    for _ in range(warmup):
        for fn in cells.values():
            jax.block_until_ready(fn())
    best = {k: float("inf") for k in cells}
    for _ in range(iters):
        for k, fn in cells.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _trainer(algo, n, num_envs, collect_steps, num_updates, batch_size,
             epochs, donate):
    env = make("pendulum")
    if algo == "ppo":
        agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim, hidden=HIDDEN)
        pcfg = PopulationConfig(size=n, strategy="none",
                                backend="vectorized", donate=donate)
        trainer = PopTrainer(agent, pcfg, seed=0)
        trainer.attach_rollout(env, num_envs=num_envs,
                               collect_steps=collect_steps,
                               batch_size=batch_size, epochs=epochs,
                               eval_envs=1)
    else:
        agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim,
                            hidden=HIDDEN)
        pcfg = PopulationConfig(size=n, strategy="none",
                                backend="vectorized", num_steps=num_updates,
                                donate=donate)
        trainer = PopTrainer(agent, pcfg, seed=0)
        trainer.attach_rollout(env, num_envs=num_envs,
                               collect_steps=collect_steps,
                               batch_size=batch_size, buffer_capacity=10_000,
                               eval_envs=1)
    return agent, trainer


def _probe_single_jit(trainer) -> bool:
    """The acceptance probe: after warm-up, one fused iteration must not
    move a single byte between host and device implicitly."""
    trainer.env_iteration()   # compile outside the guard
    try:
        with jax.transfer_guard("disallow"):
            trainer.env_iteration()
        return True
    except Exception:
        return False


def _unfused_td3_iteration(agent, trainer, n, collect_steps, num_updates,
                           batch_size):
    """The pre-engine off-policy loop: same phases, separate dispatches,
    host sync between each (hand-rolled loops synced on buffer counters /
    fitness)."""
    engine = trainer.rollout
    collector = engine.collector
    collect = jax.jit(lambda actors, vs, key: collector.collect(
        actors, vs, key, collect_steps))
    insert = jax.jit(jax.vmap(buffer_add))

    def _sample(bufs, key):
        keys = jax.random.split(key, num_updates * n)
        keys = keys.reshape((num_updates, n) + keys.shape[1:])
        return jax.vmap(jax.vmap(lambda b, kk: buffer_sample(
            b, kk, batch_size)), in_axes=(None, 0))(bufs, keys)

    sample = jax.jit(_sample)
    update = make_update(agent, "vectorized", num_steps=num_updates,
                         donate=False)

    box = {"state": trainer.state, "bufs": engine.bufs,
           "vstate": engine.vstate, "key": jax.random.PRNGKey(1)}

    def iteration():
        box["key"], kc, ks = jax.random.split(box["key"], 3)
        actors = agent.actor_params(box["state"])
        box["vstate"], traj = collect(actors, box["vstate"], kc)
        # hand-rolled loops read the collected returns on host every
        # iteration to drive PBT/CEM fitness — part of the pattern's cost
        returns = np.asarray(traj["reward"]).sum(-1)
        box["bufs"] = insert(box["bufs"], traj)
        jax.block_until_ready(box["bufs"].total)
        batches = sample(box["bufs"], ks)
        jax.block_until_ready(batches)
        box["state"], metrics = update(box["state"], batches, None)
        return metrics

    return iteration


def _unfused_ppo_iteration(agent, trainer, collect_steps):
    """The pre-engine on-policy loop: collect, then GAE + minibatch
    building, then the epoch update — three dispatches with host syncs
    (hand-rolled PPO loops also pull the rollout back for numpy GAE; the
    host sync stands in for that round-trip)."""
    engine = trainer.rollout
    collector = engine.collector
    collect = jax.jit(lambda actors, vs, key: collector.collect(
        actors, vs, key, collect_steps, flat=False))
    from repro.data import traj_add, traj_reset
    store = jax.jit(lambda bufs, traj: jax.vmap(traj_add)(
        jax.vmap(traj_reset)(bufs), traj))
    batches_fn = jax.jit(
        lambda bufs, actors, key: engine.population_batches(
            bufs, actors, None, key))
    update = make_update(agent, "vectorized", num_steps=engine.num_steps,
                         donate=False)

    box = {"state": trainer.state, "bufs": engine.bufs,
           "vstate": engine.vstate, "key": jax.random.PRNGKey(1)}

    def iteration():
        box["key"], kc, kp = jax.random.split(box["key"], 3)
        actors = agent.actor_params(box["state"])
        box["vstate"], traj = collect(actors, box["vstate"], kc)
        returns = np.asarray(traj["reward"]).sum(-1)   # host fitness read
        box["bufs"] = store(box["bufs"], traj)
        batches = batches_fn(box["bufs"], actors, kp)
        jax.block_until_ready(batches)
        box["state"], metrics = update(box["state"], batches, None)
        return metrics

    return iteration


EPOCH_LEN = 4   # iterations per fused-epoch program (one jitted call)


# ---------------------------------------------------------------- overlap
def _sweep_trainer(env_name, num_envs, impl, *, pop, collect_steps,
                   num_updates, batch_size):
    """One sweep cell: a td3 population on the physics env, either the
    serial fused engine (``impl="fused"``) or the double-buffered
    ``policy_lag=1`` engine (``impl="overlap"``)."""
    env = make(env_name)
    agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim,
                        hidden=HIDDEN)
    # donate=False is load-bearing for BOTH arms: CPU PJRT cannot enqueue
    # a program whose donated inputs are still being computed (the donated
    # buffer must materialize before it can be aliased), so donation turns
    # the async-dispatch pipeline back into lockstep execution — measured
    # here as every dispatch blocking for one full program.  On real
    # accelerators donation and async dispatch compose; on this backend
    # the sweep measures the pipeline, so it trades the buffer reuse away.
    pcfg = PopulationConfig(size=pop, strategy="none", backend="vectorized",
                            num_steps=num_updates, donate=False)
    trainer = PopTrainer(agent, pcfg, seed=0)
    trainer.attach_rollout(
        env, num_envs=num_envs, collect_steps=collect_steps,
        batch_size=batch_size,
        # a few iterations of history; capacity scales with the insert
        # size so the 4096-env arm doesn't allocate a 10M-step ring
        buffer_capacity=4 * num_envs * collect_steps,
        eval_envs=1, policy_lag=(1 if impl == "overlap" else None))
    return trainer


def _pbt_driver(trainer, k_iters):
    """A K-iteration PBT driver loop as one timed unit.

    Every iteration ends with the host fitness read PBT/CEM drivers do
    (``np.asarray`` on the episode stats).  For the serial engine that
    read waits for the whole collect+update program; for the overlapped
    engine the stats belong to the already-materialized previous slot, so
    the read returns immediately while the device keeps working.  The
    final drain blocks on everything (state, buffers, env state, pending
    slot) so the pipeline can't leak work past the timer."""
    eng = trainer.rollout

    def run_once():
        best = -np.inf
        for _ in range(k_iters):
            _, stats, _ = trainer.env_iteration()
            fit = float(np.asarray(jax.tree.leaves(stats)[0]).mean())
            best = max(best, fit)
        jax.block_until_ready((trainer.state, eng.bufs, eng.vstate,
                               getattr(eng, "_pending", None)))
        return best

    return run_once


def run_overlap_sweep(num_envs_list=(256, 1024, 4096), env_name="hopper2d",
                      pop=2, collect_steps=4, num_updates=2, batch_size=64,
                      k_iters=8, rounds=5, json_path=None):
    """Serial fused vs overlapped (policy_lag=1) per-iteration wall time
    across GPU-sim env counts.  Timed unit = a K-iteration driver loop
    with per-iteration host fitness reads (see :func:`_pbt_driver`);
    rounds are interleaved across cells in rotating order and the
    per-cell MEDIAN round is kept — unlike :func:`_timed_rounds`'s
    minimum, a median compares sustained throughput: on a time-shared
    box the program execution time itself varies ±10%, so a minimum
    rewards whichever arm got the luckiest scheduler draw rather than
    the schedule under test.  Steady-state recompiles during the timed
    rounds are counted per cell and must be zero.

    Expectation management: the overlap win is the host-side work hidden
    under the in-flight collect, so it needs the host to have somewhere
    to run — a second core (the CI runners) or a real accelerator (where
    the device computes on its own silicon).  On a single-core host every
    schedule spends the same CPU cycles and the split+pipeline overhead
    (~1–3%) is the whole story; the JSONL records whatever this box can
    actually show, it does not assume the win."""
    emit(["bench", "env", "impl", "pop", "num_envs", "ms_per_iter",
          "env_steps_per_s_per_member", "overlap_speedup",
          "steady_compiles"])
    cells = {}
    for num_envs in num_envs_list:
        for impl in ("fused", "overlap"):
            trainer = _sweep_trainer(env_name, num_envs, impl, pop=pop,
                                     collect_steps=collect_steps,
                                     num_updates=num_updates,
                                     batch_size=batch_size)
            cells[(num_envs, impl)] = _pbt_driver(trainer, k_iters)
    for fn in cells.values():   # warm: compile + fill buffers past `can`
        fn()

    compiles = {k: 0 for k in cells}
    current = [None]

    def _on_compile(_event, _secs):
        if current[0] is not None:
            compiles[current[0]] += 1

    unregister = compat.register_compile_listener(_on_compile)
    samples = {k: [] for k in cells}
    order = list(cells)
    try:
        for r in range(rounds):
            # rotate the start cell so scheduler drift over the run does
            # not systematically favour whichever arm runs first
            for key in order[r % len(order):] + order[:r % len(order)]:
                current[0] = key
                t0 = time.perf_counter()
                cells[key]()
                samples[key].append(time.perf_counter() - t0)
                current[0] = None
    finally:
        if unregister is not None:
            unregister()

    med = {k: float(np.median(v)) for k, v in samples.items()}
    rows = []
    for num_envs in num_envs_list:
        for impl in ("fused", "overlap"):
            t_iter = med[(num_envs, impl)] / k_iters
            row = {"bench": "actor_loop_overlap", "algo": "td3",
                   "env": env_name, "impl": impl, "pop": pop,
                   "num_envs": num_envs, "collect_steps": collect_steps,
                   "ms_per_iter": round(1e3 * t_iter, 3),
                   "env_steps_per_s_per_member": round(
                       num_envs * collect_steps / t_iter, 1),
                   "overlap_speedup": (round(
                       med[(num_envs, "fused")]
                       / med[(num_envs, "overlap")], 3)
                       if impl == "overlap" else None),
                   "steady_compiles": compiles[(num_envs, impl)]}
            rows.append(row)
            emit([row[k] for k in ("bench", "env", "impl", "pop",
                                   "num_envs", "ms_per_iter",
                                   "env_steps_per_s_per_member",
                                   "overlap_speedup", "steady_compiles")])
    if json_path:
        write_rows(rows, json_path)
    return rows


def run(pop_sizes=(1, 2, 4, 8, 16), algos=("td3", "ppo"), num_envs=1,
        collect_steps=256, num_updates=2, batch_size=16, epochs=1,
        iters=10, json_path=None):
    emit(["bench", "algo", "impl", "pop", "ms_per_iter", "env_steps_per_s",
          "rel_to_pop1", "fused_speedup", "single_jit"])
    cells, single_jit = {}, {}
    for algo in algos:
        for n in pop_sizes:
            for impl in ("fused", "unfused", "fused_epoch"):
                agent, trainer = _trainer(algo, n, num_envs, collect_steps,
                                          num_updates, batch_size, epochs,
                                          donate=impl != "unfused")
                if impl == "fused":
                    single_jit[(algo, n)] = _probe_single_jit(trainer)
                    cells[(algo, n, impl)] = trainer.env_iteration
                elif impl == "fused_epoch":
                    # EPOCH_LEN iterations as ONE jitted donated program
                    # (RolloutEngine.build_epoch) — what the eager fused
                    # arm pays per-iteration dispatch for, it pays once
                    cells[(algo, n, impl)] = (
                        lambda tr=trainer: tr.run_env_loop(
                            EPOCH_LEN, eval_every=0, fused=True))
                elif algo == "ppo":
                    cells[(algo, n, impl)] = _unfused_ppo_iteration(
                        agent, trainer, collect_steps)
                else:
                    cells[(algo, n, impl)] = _unfused_td3_iteration(
                        agent, trainer, n, collect_steps, num_updates,
                        batch_size)
    times = _timed_rounds(cells, iters=iters, warmup=2)
    for key in list(times):
        if key[2] == "fused_epoch":      # normalize to per-iteration time
            times[key] /= EPOCH_LEN

    rows = []
    for algo in algos:
        for n in pop_sizes:
            env_steps = n * num_envs * collect_steps
            for impl in ("fused", "unfused", "fused_epoch"):
                t = times[(algo, n, impl)]
                row = {"bench": "actor_loop", "algo": algo, "impl": impl,
                       "pop": n,
                       "ms_per_iter": round(1e3 * t, 3),
                       "env_steps_per_s": round(env_steps / t, 1),
                       "rel_to_pop1": round(
                           t / times[(algo, pop_sizes[0], impl)], 2),
                       "fused_speedup": round(
                           times[(algo, n, "unfused")] / t, 2),
                       "single_jit": (single_jit[(algo, n)]
                                      if impl == "fused" else None)}
                rows.append(row)
                emit([row[k] for k in ("bench", "algo", "impl", "pop",
                                       "ms_per_iter", "env_steps_per_s",
                                       "rel_to_pop1", "fused_speedup",
                                       "single_jit")])
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    ap.add_argument("--num-envs", default=None,
                    help="comma list (e.g. 256,1024,4096): run the "
                         "serial-vs-overlap sweep on the physics env "
                         "instead of the fused/unfused comparison")
    args = ap.parse_args()
    if args.num_envs is not None:
        sizes = tuple(int(s) for s in args.num_envs.split(","))
        if args.fast:
            run_overlap_sweep(sizes, k_iters=6, rounds=2,
                              json_path=args.json)
        else:
            run_overlap_sweep(sizes, json_path=args.json)
    elif args.fast:
        run(pop_sizes=(1, 2, 4), collect_steps=64, iters=3,
            json_path=args.json)
    else:
        run(json_path=args.json)
