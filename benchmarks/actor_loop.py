"""Acting-engine benchmark: fused vs unfused train iteration (paper §4).

The paper's central claim is that population training costs ~one agent only
when BOTH phases — acting and updating — are compiled and vectorized over
the population.  This harness measures one full train iteration
(collect ``collect_steps`` × ``num_envs`` env steps per member -> insert ->
sample -> ``num_updates`` chained TD3 updates) two ways:

  fused    — ``repro.rollout`` engine: ONE jitted call, everything stays on
             device (``PopTrainer.env_iteration``).
  unfused  — the pre-engine loop shape: four separately-jitted phases
             (collect / insert / sample / update) with a host sync between
             each, which is what hand-rolled loops pay every iteration.

The default shape follows the paper's acting setup — ONE env per member,
many acting steps per iteration, a short chained update — because that is
the regime where the fused/unfused and population-overhead questions are
about the *loop*, not about raw matmul throughput (this box has 2 CPU
cores, so a compute-bound update trivially scales linearly and would bury
the acting-side signal the paper is about).

Reported per population size: ms per iteration, env interactions per
second, iteration time relative to population 1 (the paper's
minimal-overhead claim), and the fused-over-unfused speedup.
``--json PATH`` additionally dumps the rows as JSON for trend tracking.
"""
import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import PopulationConfig
from repro.data import buffer_add, buffer_sample
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer, make_update
from repro.rl import td3


HIDDEN = (32, 32)   # small nets leave the 2 CPU cores idle capacity, the
                    # accelerator regime the paper's scaling claim assumes;
                    # 256-256 MLPs saturate this box at pop 2 and every arm
                    # degenerates to linear compute scaling


def _timed_rounds(cells, iters: int = 10, warmup: int = 2):
    """Time every cell round-robin and keep each cell's minimum.

    Interleaving + min is deliberate: this box is time-shared and stolen-CPU
    noise comes in phases that last longer than one arm's measurement, so
    timing the arms back-to-back makes them incomparable.  One round times
    every (pop, impl) cell once; the per-cell minimum over all rounds
    samples every machine phase for every cell."""
    for _ in range(warmup):
        for fn in cells.values():
            jax.block_until_ready(fn())
    best = {k: float("inf") for k in cells}
    for _ in range(iters):
        for k, fn in cells.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _trainer(n, num_envs, collect_steps, num_updates, batch_size, donate):
    env = make("pendulum")
    pcfg = PopulationConfig(size=n, strategy="none", backend="vectorized",
                            num_steps=num_updates, donate=donate)
    agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim,
                        hidden=HIDDEN)
    trainer = PopTrainer(agent, pcfg, seed=0)
    trainer.attach_rollout(env, num_envs=num_envs,
                           collect_steps=collect_steps,
                           batch_size=batch_size, buffer_capacity=10_000,
                           eval_envs=1)
    return agent, trainer


def _unfused_iteration(agent, trainer, n, collect_steps, num_updates,
                       batch_size):
    """The pre-engine loop: same phases, separate dispatches, host sync
    between each (hand-rolled loops synced on buffer counters / fitness)."""
    engine = trainer.rollout
    collector = engine.collector
    collect = jax.jit(lambda actors, vs, key: collector.collect(
        actors, vs, key, collect_steps))
    insert = jax.jit(jax.vmap(buffer_add))

    def _sample(bufs, key):
        keys = jax.random.split(key, num_updates * n)
        keys = keys.reshape((num_updates, n) + keys.shape[1:])
        return jax.vmap(jax.vmap(lambda b, kk: buffer_sample(
            b, kk, batch_size)), in_axes=(None, 0))(bufs, keys)

    sample = jax.jit(_sample)
    update = make_update(agent, "vectorized", num_steps=num_updates,
                         donate=False)

    box = {"state": trainer.state, "bufs": engine.bufs,
           "vstate": engine.vstate, "key": jax.random.PRNGKey(1)}

    def iteration():
        box["key"], kc, ks = jax.random.split(box["key"], 3)
        actors = agent.actor_params(box["state"])
        box["vstate"], traj = collect(actors, box["vstate"], kc)
        # hand-rolled loops read the collected returns on host every
        # iteration to drive PBT/CEM fitness — part of the pattern's cost
        returns = np.asarray(traj["reward"]).sum(-1)
        box["bufs"] = insert(box["bufs"], traj)
        jax.block_until_ready(box["bufs"].total)
        batches = sample(box["bufs"], ks)
        jax.block_until_ready(batches)
        box["state"], metrics = update(box["state"], batches, None)
        return metrics

    return iteration


def run(pop_sizes=(1, 2, 4, 8, 16), num_envs=1, collect_steps=256,
        num_updates=2, batch_size=16, iters=10, json_path=None):
    emit(["bench", "impl", "pop", "ms_per_iter", "env_steps_per_s",
          "rel_to_pop1", "fused_speedup"])
    cells = {}
    for n in pop_sizes:
        for impl in ("fused", "unfused"):
            agent, trainer = _trainer(n, num_envs, collect_steps,
                                      num_updates, batch_size,
                                      donate=impl == "fused")
            if impl == "fused":
                cells[(n, impl)] = trainer.env_iteration
            else:
                cells[(n, impl)] = _unfused_iteration(
                    agent, trainer, n, collect_steps, num_updates,
                    batch_size)
    times = _timed_rounds(cells, iters=iters, warmup=2)

    rows = []
    for n in pop_sizes:
        env_steps = n * num_envs * collect_steps
        for impl in ("fused", "unfused"):
            t = times[(n, impl)]
            row = {"bench": "actor_loop", "impl": impl, "pop": n,
                   "ms_per_iter": round(1e3 * t, 3),
                   "env_steps_per_s": round(env_steps / t, 1),
                   "rel_to_pop1": round(t / times[(pop_sizes[0], impl)], 2),
                   "fused_speedup": round(
                       times[(n, "unfused")] / times[(n, "fused")], 2)}
            rows.append(row)
            emit([row[k] for k in ("bench", "impl", "pop", "ms_per_iter",
                                   "env_steps_per_s", "rel_to_pop1",
                                   "fused_speedup")])
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(1, 2, 4), collect_steps=64, iters=3,
            json_path=args.json)
    else:
        run(json_path=args.json)
