"""Acting-engine benchmark: fused vs unfused train iteration (paper §4).

The paper's central claim is that population training costs ~one agent only
when BOTH phases — acting and updating — are compiled and vectorized over
the population.  This harness measures one full train iteration two ways
for BOTH experience kinds of the pipeline:

  td3 (off-policy, replay): collect ``collect_steps`` × ``num_envs`` env
      steps per member -> insert -> sample -> ``num_updates`` chained
      updates.
  ppo (on-policy, trajectory): collect (recording log_prob/value extras)
      -> on-device GAE -> ``epochs`` × shuffled minibatch updates.

  fused    — ``repro.rollout`` engine: ONE jitted call, everything stays on
             device (``PopTrainer.env_iteration``).  The fused arm also
             records ``single_jit``: whether a post-warmup iteration runs
             clean under ``jax.transfer_guard("disallow")`` — the
             no-host-round-trip property the engine promises.
  unfused  — the pre-engine loop shape: separately-jitted phases with a
             host sync between each, which is what hand-rolled loops pay
             every iteration.

The default shape follows the paper's acting setup — ONE env per member,
many acting steps per iteration, a short chained update — because that is
the regime where the fused/unfused and population-overhead questions are
about the *loop*, not about raw matmul throughput (this box has 2 CPU
cores, so a compute-bound update trivially scales linearly and would bury
the acting-side signal the paper is about).

Reported per (algo, population size): ms per iteration, env interactions
per second, iteration time relative to population 1 (the paper's
minimal-overhead claim), and the fused-over-unfused speedup.
``--json PATH`` additionally dumps the rows as JSON for trend tracking
(same row schema for both algos).
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_rows
from repro.configs.base import PopulationConfig
from repro.data import buffer_add, buffer_sample
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer, PPOAgent, make_update
from repro.rl import td3

HIDDEN = (32, 32)   # small nets leave the 2 CPU cores idle capacity, the
                    # accelerator regime the paper's scaling claim assumes;
                    # 256-256 MLPs saturate this box at pop 2 and every arm
                    # degenerates to linear compute scaling


def _timed_rounds(cells, iters: int = 10, warmup: int = 2):
    """Time every cell round-robin and keep each cell's minimum.

    Interleaving + min is deliberate: this box is time-shared and stolen-CPU
    noise comes in phases that last longer than one arm's measurement, so
    timing the arms back-to-back makes them incomparable.  One round times
    every (algo, pop, impl) cell once; the per-cell minimum over all rounds
    samples every machine phase for every cell."""
    for _ in range(warmup):
        for fn in cells.values():
            jax.block_until_ready(fn())
    best = {k: float("inf") for k in cells}
    for _ in range(iters):
        for k, fn in cells.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _trainer(algo, n, num_envs, collect_steps, num_updates, batch_size,
             epochs, donate):
    env = make("pendulum")
    if algo == "ppo":
        agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim, hidden=HIDDEN)
        pcfg = PopulationConfig(size=n, strategy="none",
                                backend="vectorized", donate=donate)
        trainer = PopTrainer(agent, pcfg, seed=0)
        trainer.attach_rollout(env, num_envs=num_envs,
                               collect_steps=collect_steps,
                               batch_size=batch_size, epochs=epochs,
                               eval_envs=1)
    else:
        agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim,
                            hidden=HIDDEN)
        pcfg = PopulationConfig(size=n, strategy="none",
                                backend="vectorized", num_steps=num_updates,
                                donate=donate)
        trainer = PopTrainer(agent, pcfg, seed=0)
        trainer.attach_rollout(env, num_envs=num_envs,
                               collect_steps=collect_steps,
                               batch_size=batch_size, buffer_capacity=10_000,
                               eval_envs=1)
    return agent, trainer


def _probe_single_jit(trainer) -> bool:
    """The acceptance probe: after warm-up, one fused iteration must not
    move a single byte between host and device implicitly."""
    trainer.env_iteration()   # compile outside the guard
    try:
        with jax.transfer_guard("disallow"):
            trainer.env_iteration()
        return True
    except Exception:
        return False


def _unfused_td3_iteration(agent, trainer, n, collect_steps, num_updates,
                           batch_size):
    """The pre-engine off-policy loop: same phases, separate dispatches,
    host sync between each (hand-rolled loops synced on buffer counters /
    fitness)."""
    engine = trainer.rollout
    collector = engine.collector
    collect = jax.jit(lambda actors, vs, key: collector.collect(
        actors, vs, key, collect_steps))
    insert = jax.jit(jax.vmap(buffer_add))

    def _sample(bufs, key):
        keys = jax.random.split(key, num_updates * n)
        keys = keys.reshape((num_updates, n) + keys.shape[1:])
        return jax.vmap(jax.vmap(lambda b, kk: buffer_sample(
            b, kk, batch_size)), in_axes=(None, 0))(bufs, keys)

    sample = jax.jit(_sample)
    update = make_update(agent, "vectorized", num_steps=num_updates,
                         donate=False)

    box = {"state": trainer.state, "bufs": engine.bufs,
           "vstate": engine.vstate, "key": jax.random.PRNGKey(1)}

    def iteration():
        box["key"], kc, ks = jax.random.split(box["key"], 3)
        actors = agent.actor_params(box["state"])
        box["vstate"], traj = collect(actors, box["vstate"], kc)
        # hand-rolled loops read the collected returns on host every
        # iteration to drive PBT/CEM fitness — part of the pattern's cost
        returns = np.asarray(traj["reward"]).sum(-1)
        box["bufs"] = insert(box["bufs"], traj)
        jax.block_until_ready(box["bufs"].total)
        batches = sample(box["bufs"], ks)
        jax.block_until_ready(batches)
        box["state"], metrics = update(box["state"], batches, None)
        return metrics

    return iteration


def _unfused_ppo_iteration(agent, trainer, collect_steps):
    """The pre-engine on-policy loop: collect, then GAE + minibatch
    building, then the epoch update — three dispatches with host syncs
    (hand-rolled PPO loops also pull the rollout back for numpy GAE; the
    host sync stands in for that round-trip)."""
    engine = trainer.rollout
    collector = engine.collector
    collect = jax.jit(lambda actors, vs, key: collector.collect(
        actors, vs, key, collect_steps, flat=False))
    from repro.data import traj_add, traj_reset
    store = jax.jit(lambda bufs, traj: jax.vmap(traj_add)(
        jax.vmap(traj_reset)(bufs), traj))
    batches_fn = jax.jit(
        lambda bufs, actors, key: engine.population_batches(
            bufs, actors, None, key))
    update = make_update(agent, "vectorized", num_steps=engine.num_steps,
                         donate=False)

    box = {"state": trainer.state, "bufs": engine.bufs,
           "vstate": engine.vstate, "key": jax.random.PRNGKey(1)}

    def iteration():
        box["key"], kc, kp = jax.random.split(box["key"], 3)
        actors = agent.actor_params(box["state"])
        box["vstate"], traj = collect(actors, box["vstate"], kc)
        returns = np.asarray(traj["reward"]).sum(-1)   # host fitness read
        box["bufs"] = store(box["bufs"], traj)
        batches = batches_fn(box["bufs"], actors, kp)
        jax.block_until_ready(batches)
        box["state"], metrics = update(box["state"], batches, None)
        return metrics

    return iteration


EPOCH_LEN = 4   # iterations per fused-epoch program (one jitted call)


def run(pop_sizes=(1, 2, 4, 8, 16), algos=("td3", "ppo"), num_envs=1,
        collect_steps=256, num_updates=2, batch_size=16, epochs=1,
        iters=10, json_path=None):
    emit(["bench", "algo", "impl", "pop", "ms_per_iter", "env_steps_per_s",
          "rel_to_pop1", "fused_speedup", "single_jit"])
    cells, single_jit = {}, {}
    for algo in algos:
        for n in pop_sizes:
            for impl in ("fused", "unfused", "fused_epoch"):
                agent, trainer = _trainer(algo, n, num_envs, collect_steps,
                                          num_updates, batch_size, epochs,
                                          donate=impl != "unfused")
                if impl == "fused":
                    single_jit[(algo, n)] = _probe_single_jit(trainer)
                    cells[(algo, n, impl)] = trainer.env_iteration
                elif impl == "fused_epoch":
                    # EPOCH_LEN iterations as ONE jitted donated program
                    # (RolloutEngine.build_epoch) — what the eager fused
                    # arm pays per-iteration dispatch for, it pays once
                    cells[(algo, n, impl)] = (
                        lambda tr=trainer: tr.run_env_loop(
                            EPOCH_LEN, eval_every=0, fused=True))
                elif algo == "ppo":
                    cells[(algo, n, impl)] = _unfused_ppo_iteration(
                        agent, trainer, collect_steps)
                else:
                    cells[(algo, n, impl)] = _unfused_td3_iteration(
                        agent, trainer, n, collect_steps, num_updates,
                        batch_size)
    times = _timed_rounds(cells, iters=iters, warmup=2)
    for key in list(times):
        if key[2] == "fused_epoch":      # normalize to per-iteration time
            times[key] /= EPOCH_LEN

    rows = []
    for algo in algos:
        for n in pop_sizes:
            env_steps = n * num_envs * collect_steps
            for impl in ("fused", "unfused", "fused_epoch"):
                t = times[(algo, n, impl)]
                row = {"bench": "actor_loop", "algo": algo, "impl": impl,
                       "pop": n,
                       "ms_per_iter": round(1e3 * t, 3),
                       "env_steps_per_s": round(env_steps / t, 1),
                       "rel_to_pop1": round(
                           t / times[(algo, pop_sizes[0], impl)], 2),
                       "fused_speedup": round(
                           times[(algo, n, "unfused")] / t, 2),
                       "single_jit": (single_jit[(algo, n)]
                                      if impl == "fused" else None)}
                rows.append(row)
                emit([row[k] for k in ("bench", "algo", "impl", "pop",
                                       "ms_per_iter", "env_steps_per_s",
                                       "rel_to_pop1", "fused_speedup",
                                       "single_jit")])
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(1, 2, 4), collect_steps=64, iters=3,
            json_path=args.json)
    else:
        run(json_path=args.json)
