"""Shared benchmark utilities."""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp


def timeit(fn, *args, iters: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) with device sync, after warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def td3_batch(key, n, b=256, obs=17, act=6):
    """HalfCheetah-v2 dimensions (the paper's Fig. 2 workload)."""
    ks = jax.random.split(key, 5)
    return {
        "obs": jax.random.normal(ks[0], (n, b, obs)),
        "action": jax.random.uniform(ks[1], (n, b, act), minval=-1, maxval=1),
        "reward": jax.random.normal(ks[2], (n, b)),
        "next_obs": jax.random.normal(ks[3], (n, b, obs)),
        "done": jnp.zeros((n, b)),
    }


def emit(row):
    print(",".join(str(x) for x in row), flush=True)


def write_rows(rows, path):
    """Persist benchmark result rows as JSONL in the telemetry row schema
    (``kind="bench"``, stamped ``t``) — the SAME format ``launch/train.py``
    run logs use, so ``tools/report.py --check`` validates CI's benchmark
    artifacts and training telemetry with one loader, and trend tooling
    reads both with one parser."""
    from repro.telemetry import JSONLSink

    with JSONLSink(path, strict=True) as sink:
        for row in rows:
            sink.write(dict(row, kind="bench"))
    print(f"wrote {path} ({len(rows)} rows)")
