"""Paper Fig. 2: update-step time vs population size per implementation.

Arms are (backend x num_steps) cells of the unified ``repro.pop`` API — the
same registry every consumer uses, so what we benchmark is literally what
trains (this runtime has no CUDA/torch — Torch arms are reported as n/a with
the paper's published qualitative result quoted in EXPERIMENTS.md):
  jax_sequential_1   — backend="sequential": one jit'd single-agent step,
                       python loop over members
  jax_sequential_50  — same, 50 steps chained per call (paper's async trick)
  jax_vectorized_1   — backend="vectorized": jit(vmap(step)), the protocol
  jax_vectorized_50  — jit(vmap(50 chained steps))
  jax_islands_1/50   — backend="islands": member groups shard_mapped over
                       the "pop" axis of an IslandLayout (one island on a
                       single device; run under the 8-fake-device flag for
                       the multi-accelerator shape)
  jax_fused_adam_*   — vectorized with the optimizer hoisted to population
                       level (``repro.optim.population_adam`` — the
                       ``kernels/pop_adam`` layout, jnp fallback off-TPU)
  jax_fused_full_*   — fused_adam + fused_linear: member forwards routed
                       through the population-batched ``pop_*_apply``
                       family (``kernels/pop_matmul`` layout)
Reported: ms per *member-update-step* and speedup vs jax_sequential_1.
``--json PATH`` dumps the rows in the telemetry ``bench`` schema
(validated in CI by ``tools/report.py --check``).
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, td3_batch, timeit, write_rows
from repro.pop import ModuleAgent, make_update
from repro.rl import td3, sac

OBS, ACT = 17, 6


def run(pop_sizes=(1, 2, 4, 8, 16), num_steps_chained=10, agents=("td3", "sac"),
        iters=3, json_path=None):
    key = jax.random.PRNGKey(0)
    emit(["bench", "agent", "impl", "pop", "ms_per_member_step", "speedup_vs_seq1"])
    rows = []
    for agent_name in agents:
        module = {"td3": td3, "sac": sac}[agent_name]
        agent = ModuleAgent(module, OBS, ACT)
        # fused variants share the module (and, via the same PRNG key, the
        # same initial population) but route the optimizer / linears
        # through the population-level kernels
        fused_variants = {
            "fused_adam": ModuleAgent(module, OBS, ACT, fused_adam=True),
            "fused_full": ModuleAgent(module, OBS, ACT, fused_adam=True,
                                      fused_linear=True),
        }
        base_ms = None
        for n in pop_sizes:
            pop = agent.population_init(key, n)
            b1 = td3_batch(key, n)
            bk = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (num_steps_chained,) + x.shape),
                b1)
            arms = {}
            for backend in ("sequential", "vectorized", "islands"):
                arms[f"jax_{backend}_1"] = (
                    make_update(agent, backend, num_steps=1, donate=False),
                    pop, b1, 1)
                arms[f"jax_{backend}_{num_steps_chained}"] = (
                    make_update(agent, backend, num_steps=num_steps_chained,
                                donate=False), pop, bk, num_steps_chained)
            for vname, vagent in fused_variants.items():
                vpop = vagent.population_init(key, n)
                arms[f"jax_{vname}_1"] = (
                    make_update(vagent, "vectorized", num_steps=1,
                                donate=False), vpop, b1, 1)
                arms[f"jax_{vname}_{num_steps_chained}"] = (
                    make_update(vagent, "vectorized",
                                num_steps=num_steps_chained,
                                donate=False), vpop, bk, num_steps_chained)
            for name, (fn, state0, batch, steps) in arms.items():
                t = timeit(lambda: fn(state0, batch, None), iters=iters)
                ms = 1e3 * t / (n * steps)
                if name == "jax_sequential_1" and n == 1:
                    base_ms = ms
                speedup = round(base_ms / ms, 2) if base_ms else ""
                emit(["population_update", agent_name, name, n,
                      round(ms, 3), speedup])
                rows.append({"bench": "population_update",
                             "agent": agent_name, "impl": name, "pop": n,
                             "ms_per_member_step": round(ms, 3),
                             "speedup_vs_seq1": speedup or None})
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--json", default=None, help="also dump rows as JSONL")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(1, 2, 4), agents=("td3",), iters=2,
            json_path=args.json)
    else:
        run(json_path=args.json)
