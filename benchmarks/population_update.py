"""Paper Fig. 2: update-step time vs population size per implementation.

Arms (this runtime has no CUDA/torch — Torch arms are reported as n/a with
the paper's published qualitative result quoted in EXPERIMENTS.md):
  jax_sequential_1   — one jit'd single-agent step, python loop over members
  jax_sequential_50  — same, 50 steps chained per call (paper's async trick)
  jax_vectorized_1   — jit(vmap(step))            (the paper's protocol)
  jax_vectorized_50  — jit(vmap(50 chained steps))
Reported: ms per *member-update-step* and speedup vs jax_sequential_1.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, td3_batch, timeit
from repro.core import population_init, sequential_update, vectorized_update
from repro.rl import td3, sac

OBS, ACT = 17, 6


def run(pop_sizes=(1, 2, 4, 8, 16), num_steps_chained=10, agents=("td3", "sac"),
        iters=3):
    key = jax.random.PRNGKey(0)
    emit(["bench", "agent", "impl", "pop", "ms_per_member_step", "speedup_vs_seq1"])
    for agent_name in agents:
        mod = {"td3": td3, "sac": sac}[agent_name]
        base_ms = None
        for n in pop_sizes:
            pop = population_init(lambda k: mod.init(k, OBS, ACT), key, n)
            b1 = td3_batch(key, n)
            bk = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (num_steps_chained,) + x.shape),
                b1)
            arms = {
                "jax_sequential_1": (sequential_update(mod.update, 1), b1, 1),
                f"jax_sequential_{num_steps_chained}":
                    (sequential_update(mod.update, num_steps_chained), bk,
                     num_steps_chained),
                "jax_vectorized_1":
                    (vectorized_update(mod.update, 1, donate=False), b1, 1),
                f"jax_vectorized_{num_steps_chained}":
                    (vectorized_update(mod.update, num_steps_chained,
                                       donate=False), bk, num_steps_chained),
            }
            for name, (fn, batch, steps) in arms.items():
                t = timeit(lambda: fn(pop, batch, None), iters=iters)
                ms = 1e3 * t / (n * steps)
                if name == "jax_sequential_1" and n == 1:
                    base_ms = ms
                emit(["population_update", agent_name, name, n, round(ms, 3),
                      round(base_ms / ms, 2) if base_ms else ""])


if __name__ == "__main__":
    run()
