"""Paper Fig. 2: update-step time vs population size per implementation.

Arms are (backend x num_steps) cells of the unified ``repro.pop`` API — the
same registry every consumer uses, so what we benchmark is literally what
trains (this runtime has no CUDA/torch — Torch arms are reported as n/a with
the paper's published qualitative result quoted in EXPERIMENTS.md):
  jax_sequential_1   — backend="sequential": one jit'd single-agent step,
                       python loop over members
  jax_sequential_50  — same, 50 steps chained per call (paper's async trick)
  jax_vectorized_1   — backend="vectorized": jit(vmap(step)), the protocol
  jax_vectorized_50  — jit(vmap(50 chained steps))
  jax_islands_1/50   — backend="islands": member groups shard_mapped over
                       the "pop" axis of an IslandLayout (one island on a
                       single device; run under the 8-fake-device flag for
                       the multi-accelerator shape)
Reported: ms per *member-update-step* and speedup vs jax_sequential_1.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, td3_batch, timeit
from repro.pop import ModuleAgent, make_update
from repro.rl import td3, sac

OBS, ACT = 17, 6


def run(pop_sizes=(1, 2, 4, 8, 16), num_steps_chained=10, agents=("td3", "sac"),
        iters=3):
    key = jax.random.PRNGKey(0)
    emit(["bench", "agent", "impl", "pop", "ms_per_member_step", "speedup_vs_seq1"])
    for agent_name in agents:
        agent = ModuleAgent({"td3": td3, "sac": sac}[agent_name], OBS, ACT)
        base_ms = None
        for n in pop_sizes:
            pop = agent.population_init(key, n)
            b1 = td3_batch(key, n)
            bk = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (num_steps_chained,) + x.shape),
                b1)
            arms = {}
            for backend in ("sequential", "vectorized", "islands"):
                arms[f"jax_{backend}_1"] = (
                    make_update(agent, backend, num_steps=1, donate=False),
                    b1, 1)
                arms[f"jax_{backend}_{num_steps_chained}"] = (
                    make_update(agent, backend, num_steps=num_steps_chained,
                                donate=False), bk, num_steps_chained)
            for name, (fn, batch, steps) in arms.items():
                t = timeit(lambda: fn(pop, batch, None), iters=iters)
                ms = 1e3 * t / (n * steps)
                if name == "jax_sequential_1" and n == 1:
                    base_ms = ms
                emit(["population_update", agent_name, name, n, round(ms, 3),
                      round(base_ms / ms, 2) if base_ms else ""])


if __name__ == "__main__":
    run()
