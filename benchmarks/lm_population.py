"""LM population training throughput: tokens/sec/member across backends.

The LM analogue of ``benchmarks/population_update.py`` — population LM
training (``rwkv6_test``, the tiny fp32 config) through the same backend
registry the RL workloads use, measuring:

  * ``sequential``        — the paper's Jax (Sequential) baseline: one jit'd
                            single-member train step looped over members.
  * ``vectorized``        — jit(vmap(train_step)), stock optax under vmap.
  * ``vectorized+fused``  — the hoisted ``repro.optim.population_adam``
                            update (``PopulationConfig.fused_adam``),
                            bitwise-equal to stock on fp32 params.

Per-member PBT hypers (lr_scale / weight_decay / warmup_frac) ride along as
(N,) arrays so the measured path is the real PBT hot path, not the
hypers=None fast path.  Each arm asserts ZERO steady-state recompiles via
``repro.compat.register_compile_listener`` (registered after warmup): a
recompile inside the timed loop invalidates the throughput number, so it is
an error, not a footnote.

CSV columns: impl, pop, batch, seq, ms_per_step, tokens_per_sec_per_member.
``--json PATH`` additionally writes telemetry-schema JSONL rows
(``kind="bench"``) via ``benchmarks.common.write_rows`` for
``tools/report.py --check`` in CI.
"""
import argparse

from common import emit, timeit, write_rows  # noqa: E402 (sys.path in common)

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import TrainConfig, get_config
from repro.pop import make_update
from repro.pop.agent import LMAgent


def _make_arm(cfg, tcfg, pop, batch, seq, *, backend, fused):
    agent = LMAgent(cfg, tcfg, fused_adam=fused)
    keys = jax.random.split(jax.random.PRNGKey(0), pop)
    state = jax.vmap(agent.init)(keys)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (pop, batch, seq),
                                0, cfg.vocab_size)
    hypers = {
        "lr_scale": jnp.linspace(0.5, 2.0, pop),
        "weight_decay": jnp.full((pop,), tcfg.weight_decay, jnp.float32),
        "warmup_frac": jnp.full((pop,), 0.1, jnp.float32),
    }
    update = make_update(agent, backend, num_steps=1, donate=False)
    return update, state, {"tokens": tokens}, hypers


def run(pop_sizes=(1, 4, 8), batch=4, seq=64, iters=3, json_path=None):
    cfg = get_config("rwkv6_test")
    tcfg = TrainConfig(total_steps=1000, warmup_steps=100, lr=3e-4,
                       weight_decay=0.1)
    arms = [("sequential", "sequential", False),
            ("vectorized", "vectorized", False),
            ("vectorized+fused", "vectorized", True)]

    emit(["impl", "pop", "batch", "seq", "ms_per_step",
          "tokens_per_sec_per_member"])
    rows = []
    for pop in pop_sizes:
        for impl, backend, fused in arms:
            update, state, batches, hypers = _make_arm(
                cfg, tcfg, pop, batch, seq, backend=backend, fused=fused)
            # warmup OUTSIDE the compile watch: first call compiles
            jax.block_until_ready(update(state, batches, hypers))
            steady = []
            unregister = compat.register_compile_listener(
                lambda event, secs: steady.append(event))
            t = timeit(update, state, batches, hypers,
                       iters=iters, warmup=0)
            if unregister is not None:
                unregister()
            if steady:
                raise AssertionError(
                    f"{impl} pop={pop}: {len(steady)} steady-state "
                    f"recompile(s) inside the timed loop: {steady}")
            tps_member = batch * seq / t
            emit([impl, pop, batch, seq, round(t * 1e3, 3),
                  round(tps_member, 1)])
            rows.append({"bench": "lm_population", "impl": impl,
                         "pop": pop, "batch": batch, "seq": seq,
                         "ms_per_step": t * 1e3,
                         "tokens_per_sec_per_member": tps_member,
                         "steady_compiles": len(steady)})
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="tiny grid for CI (pop 1 and 2, 1 timed iter)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write telemetry-schema JSONL rows")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(1, 2), batch=2, seq=32, iters=1,
            json_path=args.json)
    else:
        run(json_path=args.json)
