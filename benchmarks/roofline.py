"""Roofline report: reads the dry-run artifact (dryrun_results.json) and
prints the per-(arch x shape x mesh) three-term table plus the
MODEL_FLOPS / HLO_FLOPS usefulness ratio (task spec §Roofline).

``--fused-epoch`` adds a modeled-vs-measured arm for the RL side: the
fused train–evolve epoch (``RolloutEngine.build_epoch``) is AOT-compiled,
its XLA cost analysis (flops / bytes accessed) is divided by
micro-benchmarked machine peaks (a square matmul for flops, a streaming
add for bandwidth), and the resulting roofline time
``max(flops/peak_flops, bytes/peak_bw)`` is printed next to the measured
steady-state wall time of the compiled program.  The ratio says how far
the fused program sits from the machine's roofline — small nets on CPU
are expected to land memory-bound and several x off peak (dispatch-free,
but op-granularity-bound); the number is the honest gap report."""
import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_rows
from repro.configs import LM_SHAPES, get_config
from repro.models.accounting import model_flops, param_count, active_param_count

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
RESULTS_OPT = os.path.join(os.path.dirname(__file__), "..",
                           "dryrun_results_opt.json")


def run(path=None, single_pod_only=False):
    if path is None:
        for p, tag in ((RESULTS, "baseline (paper-faithful)"),
                       (RESULTS_OPT, "optimized (§Perf)")):
            emit(["roofline", f"--- {tag} ---"])
            run(p, single_pod_only)
        return
    if not os.path.exists(path):
        emit(["roofline", "SKIPPED — run python -m repro.launch.dryrun --all "
              "--both-meshes --out dryrun_results.json first"])
        return
    rows = json.load(open(path))
    emit(["bench", "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
          "t_collective_s", "bottleneck", "model_flops_ratio",
          "hbm_gb_per_device"])
    by_name = {}
    for r in rows:
        if "bottleneck" not in r:
            continue
        mesh = "x".join(str(v) for v in r["mesh"].values())
        if single_pod_only and len(r["mesh"]) == 3:
            continue
        arch_id = r["arch"].replace("-", "_").replace(".", "_")
        cfg = get_config(arch_id)
        shape = LM_SHAPES[r["shape"]]
        mf = model_flops(cfg, shape) / r["num_devices"]
        ratio = mf / max(r["hlo_flops_per_device"], 1.0)
        hbm = (r["bytes_per_device"]["arguments"] +
               r["bytes_per_device"]["temps"]) / 1e9
        emit(["roofline", r["arch"], r["shape"], mesh,
              round(r["t_compute"], 4), round(r["t_memory"], 4),
              round(r["t_collective"], 4), r["bottleneck"],
              round(ratio, 3), round(hbm, 2)])


def _machine_peaks():
    """Micro-benchmark this box: sustained matmul flops and streaming
    memory bandwidth — the two roofline ceilings."""
    n = 512
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda a, b: a @ b)
    t = timeit(lambda: mm(a, a), iters=5)
    peak_flops = 2.0 * n ** 3 / t
    m = 1 << 23   # 32 MB float32: far past any cache on this box
    x = jnp.ones((m,), jnp.float32)
    add = jax.jit(lambda x: x + 1.0)
    t = timeit(lambda: add(x), iters=5)
    peak_bw = 2.0 * 4.0 * m / t   # one read + one write per element
    return peak_flops, peak_bw


def run_fused_epoch(algo="td3", pop=4, epoch_len=4, num_envs=4,
                    collect_steps=64, json_path=None):
    """Modeled-vs-measured roofline for the fused train–evolve epoch."""
    from repro.configs.base import PopulationConfig
    from repro.envs import make
    from repro.pop import ModuleAgent, PopTrainer
    from repro.rl import td3 as td3_mod

    env = make("pendulum")
    agent = ModuleAgent(td3_mod, env.spec.obs_dim, env.spec.act_dim,
                        hidden=(32, 32))
    # donate=False so the compiled program can be re-invoked on the same
    # arguments for steady-state timing
    pcfg = PopulationConfig(size=pop, strategy="none",
                            backend="vectorized", num_steps=2,
                            donate=False)
    trainer = PopTrainer(agent, pcfg, seed=0)
    trainer.attach_rollout(env, num_envs=num_envs,
                           collect_steps=collect_steps, batch_size=64,
                           buffer_capacity=10_000, eval_envs=1)
    engine = trainer.rollout
    epoch_fn = engine.build_epoch(epoch_len=epoch_len, eval_every=0,
                                  donate=False)
    args = (trainer.state, engine.bufs, engine.vstate, trainer.hypers,
            trainer.strategy.export_state(), trainer.key)
    compiled = epoch_fn.lower(*args).compile()

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))

    peak_flops, peak_bw = _machine_peaks()
    t_compute = flops / peak_flops
    t_memory = bytes_accessed / peak_bw
    t_modeled = max(t_compute, t_memory)
    t_measured = timeit(lambda: compiled(*args), iters=5)

    emit(["bench", "algo", "pop", "epoch_len", "gflops", "mbytes",
          "t_modeled_ms", "t_measured_ms", "bound", "roofline_gap"])
    row = {"bench": "roofline_fused_epoch", "algo": algo, "pop": pop,
           "epoch_len": epoch_len, "num_envs": num_envs,
           "collect_steps": collect_steps,
           "gflops": round(flops / 1e9, 4),
           "mbytes": round(bytes_accessed / 1e6, 3),
           "peak_gflops_per_s": round(peak_flops / 1e9, 2),
           "peak_gb_per_s": round(peak_bw / 1e9, 2),
           "t_modeled_ms": round(1e3 * t_modeled, 3),
           "t_measured_ms": round(1e3 * t_measured, 3),
           "bound": "compute" if t_compute >= t_memory else "memory",
           "roofline_gap": (round(t_measured / t_modeled, 2)
                            if t_modeled > 0 else None)}
    emit([row[k] for k in ("bench", "algo", "pop", "epoch_len", "gflops",
                           "mbytes", "t_modeled_ms", "t_measured_ms",
                           "bound", "roofline_gap")])
    if json_path:
        write_rows([row], json_path)
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused-epoch", action="store_true",
                    help="modeled-vs-measured roofline of the fused "
                         "train-evolve epoch instead of the LM dry-run "
                         "table")
    ap.add_argument("--json", default=None, help="dump rows as JSONL")
    args = ap.parse_args()
    if args.fused_epoch:
        run_fused_epoch(json_path=args.json)
    else:
        run()
