"""Roofline report: reads the dry-run artifact (dryrun_results.json) and
prints the per-(arch x shape x mesh) three-term table plus the
MODEL_FLOPS / HLO_FLOPS usefulness ratio (task spec §Roofline)."""
import json
import os

from benchmarks.common import emit
from repro.configs import LM_SHAPES, get_config
from repro.models.accounting import model_flops, param_count, active_param_count

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
RESULTS_OPT = os.path.join(os.path.dirname(__file__), "..",
                           "dryrun_results_opt.json")


def run(path=None, single_pod_only=False):
    if path is None:
        for p, tag in ((RESULTS, "baseline (paper-faithful)"),
                       (RESULTS_OPT, "optimized (§Perf)")):
            emit(["roofline", f"--- {tag} ---"])
            run(p, single_pod_only)
        return
    if not os.path.exists(path):
        emit(["roofline", "SKIPPED — run python -m repro.launch.dryrun --all "
              "--both-meshes --out dryrun_results.json first"])
        return
    rows = json.load(open(path))
    emit(["bench", "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
          "t_collective_s", "bottleneck", "model_flops_ratio",
          "hbm_gb_per_device"])
    by_name = {}
    for r in rows:
        if "bottleneck" not in r:
            continue
        mesh = "x".join(str(v) for v in r["mesh"].values())
        if single_pod_only and len(r["mesh"]) == 3:
            continue
        arch_id = r["arch"].replace("-", "_").replace(".", "_")
        cfg = get_config(arch_id)
        shape = LM_SHAPES[r["shape"]]
        mf = model_flops(cfg, shape) / r["num_devices"]
        ratio = mf / max(r["hlo_flops_per_device"], 1.0)
        hbm = (r["bytes_per_device"]["arguments"] +
               r["bytes_per_device"]["temps"]) / 1e9
        emit(["roofline", r["arch"], r["shape"], mesh,
              round(r["t_compute"], 4), round(r["t_memory"], 4),
              round(r["t_collective"], 4), r["bottleneck"],
              round(ratio, 3), round(hbm, 2)])


if __name__ == "__main__":
    run()
