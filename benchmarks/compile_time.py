"""Paper Table 3: initial compilation time for a population of 20 agents,
Jax (Vectorized) with chained update steps."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, td3_batch
from repro.core import population_init, vectorized_update
from repro.rl import td3, sac

OBS, ACT = 17, 6


def run(n=20, num_steps=10):
    key = jax.random.PRNGKey(0)
    emit(["bench", "agent", "pop", "num_steps", "compile_s"])
    for name, mod in (("td3", td3), ("sac", sac)):
        pop = population_init(lambda k: mod.init(k, OBS, ACT), key, n)
        batches = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_steps,) + x.shape),
            td3_batch(key, n))
        fn = vectorized_update(mod.update, num_steps, donate=False)
        t0 = time.perf_counter()
        out = fn(pop, batches, None)
        jax.block_until_ready(out)
        emit(["compile_time", name, n, num_steps,
              round(time.perf_counter() - t0, 2)])


if __name__ == "__main__":
    run()
