"""Paper Table 3: initial compilation time for a population of 20 agents,
Jax (Vectorized) with chained update steps.

Two arms:

  * in-process (default) — one cold XLA compile per algorithm, timed
    directly (the paper's table).
  * ``--restart`` — the persistent-compilation-cache story: a child
    process compiles the same program twice, in two *separate* Python
    processes sharing one ``--compile-cache`` directory (exactly what
    ``launch/train.py --compile-cache`` / ``launch/serve.py
    --compile-cache`` do across restarts).  The first child pays the cold
    compile and populates the cache; the second deserializes the
    executable instead of rebuilding it.  Emitted rows are
    ``arm=cold`` / ``arm=warm`` plus their ratio — the restart tax the
    cache removes.

``--json PATH`` dumps all rows in the same artifact style as
``actor_loop`` / ``serve_throughput``.
"""
import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, td3_batch, write_rows
from repro.core import population_init, vectorized_update
from repro.rl import td3, sac

OBS, ACT = 17, 6


def _compile_once(mod, n, num_steps) -> float:
    """Seconds for the first (compiling) call of the chained vectorized
    update."""
    key = jax.random.PRNGKey(0)
    pop = population_init(lambda k: mod.init(k, OBS, ACT), key, n)
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_steps,) + x.shape),
        td3_batch(key, n))
    fn = vectorized_update(mod.update, num_steps, donate=False)
    t0 = time.perf_counter()
    jax.block_until_ready(fn(pop, batches, None))
    return time.perf_counter() - t0


def run(n=20, num_steps=10):
    emit(["bench", "agent", "pop", "num_steps", "compile_s"])
    rows = []
    for name, mod in (("td3", td3), ("sac", sac)):
        row = {"bench": "compile_time", "agent": name, "pop": n,
               "num_steps": num_steps,
               "compile_s": round(_compile_once(mod, n, num_steps), 2)}
        rows.append(row)
        emit([row[k] for k in ("bench", "agent", "pop", "num_steps",
                               "compile_s")])
    return rows


# ------------------------------------------------------- restart arm
def _child(cache_dir, n, num_steps):
    """One process lifetime: enable the persistent cache, compile once,
    report the wall time on stdout (the parent parses the sentinel)."""
    from repro import compat
    compat.enable_compilation_cache(cache_dir)
    print(f"compile_s={_compile_once(td3, n, num_steps):.4f}", flush=True)


def run_restart(n=20, num_steps=10, cache_dir=None):
    """Cold-vs-warm restart: two child processes, one shared cache dir."""
    tmp = None
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro_xla_cache_")
        cache_dir = tmp.name
    emit(["bench", "agent", "pop", "num_steps", "arm", "compile_s",
          "warm_over_cold"])
    rows, secs = [], {}
    try:
        for arm in ("cold", "warm"):
            out = subprocess.run(
                [sys.executable, "-m", "benchmarks.compile_time", "--child",
                 "--cache-dir", cache_dir, "--pop", str(n),
                 "--num-steps", str(num_steps)],
                capture_output=True, text=True, check=True,
                env={**os.environ, "PYTHONPATH": "src"},
                cwd=os.path.join(os.path.dirname(__file__), ".."))
            line = [l for l in out.stdout.splitlines()
                    if l.startswith("compile_s=")][-1]
            secs[arm] = float(line.split("=")[1])
            row = {"bench": "compile_time_restart", "agent": "td3",
                   "pop": n, "num_steps": num_steps, "arm": arm,
                   "compile_s": round(secs[arm], 3),
                   "warm_over_cold": round(secs[arm] / secs["cold"], 3)}
            rows.append(row)
            emit([row[k] for k in ("bench", "agent", "pop", "num_steps",
                                   "arm", "compile_s", "warm_over_cold")])
    finally:
        if tmp is not None:
            tmp.cleanup()
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--restart", action="store_true",
                    help="cold-vs-warm compile across process restarts "
                    "sharing a persistent compilation cache")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent cache dir for --restart (default: a "
                    "fresh temp dir, removed afterwards)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller population / fewer chained steps (CI)")
    ap.add_argument("--pop", type=int, default=None)
    ap.add_argument("--num-steps", type=int, default=None)
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    n = args.pop or (4 if args.fast else 20)
    num_steps = args.num_steps or (3 if args.fast else 10)
    if args.child:
        _child(args.cache_dir, n, num_steps)
        sys.exit(0)
    rows = (run_restart(n=n, num_steps=num_steps, cache_dir=args.cache_dir)
            if args.restart else run(n=n, num_steps=num_steps))
    if args.json:
        write_rows(rows, args.json)
