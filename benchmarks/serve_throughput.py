"""Serving benchmark: ensemble inference throughput and latency.

The paper's training claim — a population costs ~one member when one
compiled call covers everyone — has an inference-side mirror, and this
harness measures it: requests/sec and p50/p99 latency of
``repro.serve.BatchServer`` (every ensemble member's deterministic forward
+ the reduction as ONE jitted donated call) across population size ×
request batch size.  Latency is end-to-end as a client sees it: host-side
padding, the explicit H2D request ingress, the jitted ensemble call, and
the D2H action egress.

Reported per (pop, batch) cell: p50/p99 ms per request batch, requests/sec,
latency relative to a 1-member ensemble at the same batch (the
minimal-overhead claim, inference edition), and ``single_jit`` — whether a
warm call runs clean under ``jax.transfer_guard("disallow")`` on a
device-resident batch (the no-hidden-round-trip property).  ``--islands``
additionally runs the ``shard_map``-over-islands arm on multi-device
processes (CI's serving job fakes 8).  ``--json PATH`` dumps rows in the
same JSON-artifact style as ``actor_loop`` / ``elastic_resize`` for trend
tracking.
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_rows
from repro.envs import make
from repro.pop import ModuleAgent
from repro.rl import td3
from repro.serve import BatchServer, PolicyForward, make_serving_set

HIDDEN = (32, 32)   # same acting-regime nets as actor_loop: small enough
                    # that the 2 CPU cores measure the loop, not matmuls

FIELDS = ("bench", "algo", "impl", "mode", "pop", "batch", "p50_ms",
          "p99_ms", "req_per_s", "rel_to_pop1", "single_jit")


def _server(env, agent, n, batch, mode, mesh=None):
    """A BatchServer over a fresh n-member population (random init — the
    forward's cost doesn't care whether the params are trained), serving
    ALL members as the ensemble."""
    actors = agent.actor_params(
        agent.population_init(jax.random.PRNGKey(0), n))
    sset = make_serving_set(actors, np.arange(n), step=0,
                            fitness=np.arange(n, dtype=np.float64))
    server = BatchServer(PolicyForward.for_agent(agent), env.spec, sset,
                         max_batch=batch, mode=mode, mesh=mesh)
    return server.warmup()


def _probe_single_jit(server, obs_dim) -> bool:
    """A warm ensemble call on a device-resident padded batch must not move
    a single byte between host and device implicitly."""
    obs = server.place_request(
        np.zeros((server.max_batch, obs_dim), np.float32))
    try:
        with jax.transfer_guard("disallow"):
            jax.block_until_ready(server.infer_device(obs))
        return True
    except Exception:
        return False


def _measure(server, env, iters: int):
    """Per-request-batch wall latencies (seconds) for ``iters`` fresh
    request batches of random observations."""
    rng = np.random.default_rng(0)
    reqs = [rng.standard_normal(
        (server.max_batch, env.spec.obs_dim)).astype(np.float32)
        for _ in range(iters)]
    for obs in reqs[:3]:
        server.serve(obs)
    lat = []
    for obs in reqs:
        t0 = time.perf_counter()
        server.serve(obs)
        lat.append(time.perf_counter() - t0)
    return np.asarray(lat)


def run(pop_sizes=(1, 2, 4, 8, 16), batch_sizes=(1, 32, 256), mode="mean",
        iters=100, islands=False, json_path=None):
    env = make("pendulum")
    agent = ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim,
                        hidden=HIDDEN)
    impls = ["vmap"] + (["islands"] if islands else [])
    if islands and len(jax.devices()) == 1:
        print("# --islands on a single device: arm still runs, mesh is "
              "degenerate (set XLA_FLAGS=--xla_force_host_platform_"
              "device_count=8 for the real topology)")

    emit(list(FIELDS))
    rows = []
    base = {}
    for impl in impls:
        for n in pop_sizes:
            mesh = None
            if impl == "islands":
                from repro.elastic import plan_layout
                mesh = plan_layout(len(jax.devices()), n).mesh
            for b in batch_sizes:
                server = _server(env, agent, n, b, mode, mesh=mesh)
                single_jit = _probe_single_jit(server, env.spec.obs_dim)
                lat = _measure(server, env, iters)
                p50 = float(np.percentile(lat, 50))
                row = {"bench": "serve_throughput", "algo": "td3",
                       "impl": impl, "mode": mode, "pop": n, "batch": b,
                       "p50_ms": round(1e3 * p50, 3),
                       "p99_ms": round(1e3 * float(np.percentile(lat, 99)),
                                       3),
                       "req_per_s": round(b * len(lat) / lat.sum(), 1),
                       "rel_to_pop1": round(
                           p50 / base.setdefault((impl, b), p50), 2),
                       "single_jit": single_jit}
                rows.append(row)
                emit([row[k] for k in FIELDS])
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--mode", default="mean", choices=["mean", "vote", "best"])
    ap.add_argument("--islands", action="store_true",
                    help="add the shard_map-over-islands arm (multi-device)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(1, 2, 4), batch_sizes=(1, 64), iters=25,
            mode=args.mode, islands=args.islands, json_path=args.json)
    else:
        run(mode=args.mode, islands=args.islands, json_path=args.json)
