"""Elastic re-layout benchmark: the cost of a save -> resize -> resume cycle.

The elasticity story only matters if a re-layout is cheap relative to the
training it rescues, so this harness times the three phases of
``repro.elastic`` end to end for a ``PopTrainer`` WITH an attached rollout
engine (the realistic case — replay buffers dominate checkpoint bytes):

  save      — blocking checkpoint (device -> host -> atomic dir rename)
  restore   — build the resized trainer's first ``restore_elastic`` call:
              load + fitness-ranked member gather + device placement
  first_it  — the first fused iteration after resume (recompilation on the
              new topology, the real "time to training again" tail)

Rows are (population -> resized population) cells at the current device
count (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
for the multi-device variant — CI's tier-2 elastic job does).  ``--json
PATH`` dumps rows for trend tracking next to ``actor_loop`` /
``population_update``.
"""
import argparse
import shutil
import tempfile
import time

import jax

from benchmarks.common import emit, write_rows
from repro.configs.base import HyperSpace, PopulationConfig
from repro.elastic import restore_elastic
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3

SPACE = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),))


def _trainer(n, ckpt_dir, *, backend, buffer_capacity):
    env = make("pendulum")
    pcfg = PopulationConfig(size=n, strategy="pbt", backend=backend,
                            num_steps=2, pbt_interval=0, hyper_space=SPACE,
                            donate=False)
    tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=0, checkpoint_dir=ckpt_dir)
    tr.attach_rollout(env, num_envs=2, collect_steps=16, batch_size=32,
                      buffer_capacity=buffer_capacity, eval_envs=1)
    return tr


def _cycle(n, new_n, backend, buffer_capacity, warm_iters):
    ckpt = tempfile.mkdtemp(prefix="elastic_bench_")
    try:
        tr = _trainer(n, ckpt, backend=backend,
                      buffer_capacity=buffer_capacity)
        for _ in range(warm_iters):
            tr.env_iteration()
        tr.report_fitness(jax.numpy.arange(n, dtype=jax.numpy.float32))

        t0 = time.perf_counter()
        tr.save(blocking=True)
        t_save = time.perf_counter() - t0

        tr2 = _trainer(new_n, ckpt, backend=backend,
                       buffer_capacity=buffer_capacity)
        t0 = time.perf_counter()
        restore_elastic(tr2)
        jax.block_until_ready(tr2.state)
        t_restore = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, _, did = tr2.env_iteration()
        jax.block_until_ready(tr2.state)
        t_first = time.perf_counter() - t0
        assert bool(did), "resumed trainer should keep updating"
        return t_save, t_restore, t_first
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


def run(pop_sizes=(2, 4, 8), backend="vectorized",
        buffer_capacity=20_000, warm_iters=3, json_path=None):
    cols = ["bench", "backend", "devices", "pop", "new_pop", "save_ms",
            "restore_ms", "first_iter_ms", "cycle_ms"]
    emit(cols)
    rows = []
    devices = len(jax.devices())
    for n in pop_sizes:
        for new_n in {max(1, n // 2), n, n * 2}:
            ts, tr_, tf = _cycle(n, new_n, backend, buffer_capacity,
                                 warm_iters)
            row = {"bench": "elastic_resize", "backend": backend,
                   "devices": devices, "pop": n, "new_pop": new_n,
                   "save_ms": round(1e3 * ts, 1),
                   "restore_ms": round(1e3 * tr_, 1),
                   "first_iter_ms": round(1e3 * tf, 1),
                   "cycle_ms": round(1e3 * (ts + tr_ + tf), 1)}
            rows.append(row)
            emit([row[c] for c in cols])
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / buffers (CI mode)")
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential", "islands"])
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    if args.fast:
        run(pop_sizes=(2, 4), backend=args.backend, buffer_capacity=2_000,
            warm_iters=2, json_path=args.json)
    else:
        run(backend=args.backend, json_path=args.json)
