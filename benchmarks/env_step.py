"""Paper Table 2: steady-state per-interaction time (env step + policy).

Each timed call runs a jitted ``lax.scan`` of ``steps_per_call``
interactions that THREADS the env state and observation through the loop
(the previous version re-timed one captured transition over and over), so
what is reported is the steady-state cost of a real acting step: policy
forward + physics + auto-reset, amortized over the scan.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.envs import make
from repro.rl import dqn, sac, td3

ENVS = ("pendulum", "reacher", "mountain_car", "cartpole", "acrobot")


def run(iters=5, steps_per_call=256):
    emit(["bench", "env", "agent", "ms_per_interaction"])
    key = jax.random.PRNGKey(0)
    for env_name in ENVS:
        env = make(env_name)
        if env.spec.discrete:
            arms = (("dqn", dqn),)
        else:
            arms = (("td3", td3), ("sac", sac))
        for agent_name, mod in arms:
            st = mod.init(key, env.spec.obs_dim, env.spec.act_dim)
            params = st.q if agent_name == "dqn" else st.actor

            @jax.jit
            def steady(state, obs, k, params=params, mod=mod, env=env):
                def body(carry, _):
                    state, obs, k = carry
                    k, ka = jax.random.split(k)
                    a = mod.policy(params, obs, ka)
                    state, _, reward, _, _ = env.step(state, a)
                    return (state, env.observe(state), k), reward

                carry, rewards = jax.lax.scan(
                    body, (state, obs, k), None, length=steps_per_call)
                return carry, rewards.sum()

            state, obs = env.reset(key)
            t = timeit(lambda: steady(state, obs, key), iters=iters)
            emit(["env_step", env_name, agent_name,
                  round(1e3 * t / steps_per_call, 4)])


if __name__ == "__main__":
    run()
