"""Paper Table 2: steady-state per-interaction time (env step + policy).

Each timed call runs a jitted ``lax.scan`` of ``steps_per_call``
interactions that THREADS the env state and observation through the loop
(the previous version re-timed one captured transition over and over), so
what is reported is the steady-state cost of a real acting step: policy
forward + physics + auto-reset, amortized over the scan.

Two arms per env:

  * ``single`` — one env, one agent: the per-interaction latency floor
    (what a Python step loop would pay per call, minus the dispatch).
  * ``batched`` — ``pop`` members x ``num_envs`` envs, double-vmapped
    (member axis outside, env axis inside — the ``repro.rollout`` layout).
    Reported per-interaction time divides by the full batch, and
    ``steps_per_s_per_member`` is the acting throughput each population
    member sees — the number the GPU-sim scaling story is about.

``hopper2d`` (the physics-grade tier: 4 rigid bodies, spring joints,
penalty contacts, 5 substeps of semi-implicit Euler) sits alongside the
classic-control envs so the table shows how the acting cost model changes
when the env stops being a toy: classic control is dispatch-bound at
batch 1 and policy-bound at batch 4096; hopper2d is physics-bound
throughout.  ``--json`` dumps ``kind="bench"`` JSONL rows.
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_rows
from repro.envs import make
from repro.rl import dqn, sac, td3

ENVS = ("pendulum", "reacher", "mountain_car", "cartpole", "acrobot",
        "hopper2d")

# Cap total interactions per timed call so the 4096-env arm stays a
# sub-second call on CPU while small arms still amortize dispatch.
_MAX_STEPS_PER_CALL = 262_144


def _steady_fn(env, mod, agent_name, steps):
    """Jitted scan of ``steps`` interactions for ONE (params, state, obs)."""

    def steady(params, state, obs, k):
        def body(carry, _):
            state, obs, k = carry
            k, ka = jax.random.split(k)
            a = mod.policy(params, obs, ka)
            state, _, reward, _, _ = env.step(state, a)
            return (state, env.observe(state), k), reward

        carry, rewards = jax.lax.scan(
            body, (state, obs, k), None, length=steps)
        return carry, rewards.sum()

    return steady


def run(iters=5, steps_per_call=256, pop=4, num_envs=1024, json_path=None):
    emit(["bench", "env", "agent", "impl", "pop", "num_envs",
          "us_per_interaction", "steps_per_s_per_member"])
    key = jax.random.PRNGKey(0)
    rows = []
    for env_name in ENVS:
        env = make(env_name)
        if env.spec.discrete:
            arms = (("dqn", dqn),)
        else:
            arms = (("td3", td3), ("sac", sac))
        for agent_name, mod in arms:
            st = mod.init(key, env.spec.obs_dim, env.spec.act_dim)
            params = st.q if agent_name == "dqn" else st.actor

            for impl, n, e in (("single", 1, 1),
                               ("batched", pop, num_envs)):
                total = n * e
                steps = max(8, min(steps_per_call,
                                   _MAX_STEPS_PER_CALL // total))
                steady = _steady_fn(env, mod, agent_name, steps)
                if impl == "batched":
                    # member axis outside, env axis inside — the rollout
                    # engine's layout: per-member policy params, a batch
                    # of envs under each member
                    steady = jax.vmap(jax.vmap(steady,
                                               in_axes=(None, 0, 0, 0)))
                    pk = jax.random.split(key, n)
                    pparams = jax.vmap(
                        lambda k: mod.init(k, env.spec.obs_dim,
                                           env.spec.act_dim))(pk)
                    pparams = (pparams.q if agent_name == "dqn"
                               else pparams.actor)
                    rk = jax.random.split(key, total).reshape(
                        (n, e) + (2,))
                    state, obs = jax.vmap(jax.vmap(env.reset))(rk)
                    args = (pparams, state, obs, rk)
                else:
                    state, obs = env.reset(key)
                    args = (params, state, obs, key)
                fn = jax.jit(steady)
                t = timeit(lambda: fn(*args), iters=iters)
                per_member = e * steps / t
                row = {"bench": "env_step", "env": env_name,
                       "agent": agent_name, "impl": impl, "pop": n,
                       "num_envs": e,
                       "us_per_interaction": round(
                           1e6 * t / (total * steps), 4),
                       "steps_per_s_per_member": round(per_member, 1)}
                rows.append(row)
                emit([row[k] for k in ("bench", "env", "agent", "impl",
                                       "pop", "num_envs",
                                       "us_per_interaction",
                                       "steps_per_s_per_member")])
    if json_path:
        write_rows(rows, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller batch / fewer iters (CI mode)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    if args.fast:
        run(iters=3, steps_per_call=64, pop=2, num_envs=256,
            json_path=args.json)
    else:
        run(json_path=args.json)
