"""Paper Table 2: per-interaction time (env step + jitted policy forward)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.envs import make
from repro.rl import td3, sac


def run(iters=5):
    emit(["bench", "env", "agent", "ms_per_interaction"])
    key = jax.random.PRNGKey(0)
    for env_name in ("pendulum", "reacher", "cartpole"):
        env = make(env_name)
        for agent_name, mod in (("td3", td3), ("sac", sac)):
            if env.spec.discrete:
                continue
            st = mod.init(key, env.spec.obs_dim, env.spec.act_dim)
            actor = st.actor

            @jax.jit
            def interact(state, obs, k):
                a = mod.policy(actor, obs, k)
                return env.step(state, a)

            state, obs = env.reset(key)
            def one():
                s, o, r, d = interact(state, obs, key)
                return o
            t = timeit(one, iters=iters)
            emit(["env_step", env_name, agent_name, round(1e3 * t, 4)])


if __name__ == "__main__":
    run()
