"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

CSV rows go to stdout (``name,...,derived`` per the repo convention):
  population_update — paper Fig. 2 (update speed vs implementation x pop)
  shared_critic     — paper Fig. 4 (§4.2 shared-critic update)
  actor_loop        — (§4) fused vs unfused full train iteration
  env_step          — paper Table 2 (steady-state per-interaction time)
  compile_time      — paper Table 3 (initial compilation, pop of 20)
  roofline          — (ours) dry-run three-term roofline per arch x shape
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller pops / fewer iters (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    args = ap.parse_args()

    from benchmarks import (actor_loop, compile_time, env_step,
                            population_update, roofline, shared_critic)
    sel = set(args.only.split(",")) if args.only else None

    def want(name):
        return sel is None or name in sel

    if want("population_update"):
        if args.fast:
            population_update.run(pop_sizes=(1, 2, 4), num_steps_chained=5,
                                  agents=("td3",), iters=2)
        else:
            population_update.run()
    if want("actor_loop"):
        if args.fast:
            actor_loop.run(pop_sizes=(1, 2, 4), collect_steps=64, iters=3)
        else:
            actor_loop.run()
    if want("shared_critic"):
        shared_critic.run(pop_sizes=(2, 4) if args.fast else (2, 4, 8, 16),
                          iters=2 if args.fast else 3)
    if want("env_step"):
        env_step.run()
    if want("compile_time"):
        compile_time.run(n=4 if args.fast else 20,
                         num_steps=5 if args.fast else 10)
    if want("roofline"):
        roofline.run()


if __name__ == "__main__":
    main()
