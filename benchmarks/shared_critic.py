"""Paper Fig. 4: shared-critic population update, vectorized (§4.2) vs the
original CEM-RL sequential interleaving."""
import jax

from benchmarks.common import emit, td3_batch, timeit
from repro.core.shared import (init as shared_init,
                               make_shared_critic_update,
                               sequential_shared_critic_update)

OBS, ACT = 17, 6


def run(pop_sizes=(2, 4, 8, 16), iters=3):
    key = jax.random.PRNGKey(0)
    emit(["bench", "impl", "pop", "ms_per_update", "speedup"])
    vec = jax.jit(make_shared_critic_update())
    seq = jax.jit(sequential_shared_critic_update())
    for n in pop_sizes:
        st = shared_init(key, OBS, ACT, n)
        batch = td3_batch(key, n)
        t_seq = timeit(lambda: seq(st, batch, None), iters=iters)
        t_vec = timeit(lambda: vec(st, batch, None), iters=iters)
        emit(["shared_critic", "sequential(CEM-RL orig)", n,
              round(1e3 * t_seq, 2), 1.0])
        emit(["shared_critic", "vectorized(paper 4.2)", n,
              round(1e3 * t_vec, 2), round(t_seq / t_vec, 2)])


if __name__ == "__main__":
    run()
