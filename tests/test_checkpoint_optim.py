"""Checkpoint manager (fault tolerance) + optimizer tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         sgd, warmup_cosine)
from repro.optim.compress import compress_tree, decompress_tree

KEY = jax.random.PRNGKey(0)


def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)},
            "d": [jnp.zeros(2), jnp.full((1,), 7.0)]}
    save_pytree(tmp_path / "ck", tree, {"step": 3})
    back = load_pytree(tmp_path / "ck", tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones(3)}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree))
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest() == 30
    got, extra = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(got["w"]), 30.0)
    assert extra["step"] == 30


def test_manager_auto_resume_after_partial_write(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    tree = {"w": jnp.ones(3)}
    mgr.save(1, tree)
    # simulate a preempted writer: leftover tmp dir must be ignored
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest() == 1
    got, _ = mgr.restore(tree)
    assert got is not None


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save_async(5, {"w": jnp.full((4,), 5.0)})
    mgr.wait()
    got, extra = mgr.restore({"w": jnp.zeros(4)})
    np.testing.assert_allclose(np.asarray(got["w"]), 5.0)


def test_adam_converges_quadratic():
    init_fn, update_fn = adam(lr=0.1)
    params = {"w": jnp.full((4,), 5.0)}
    state = init_fn(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)  # d/dw w^2
        upd, state = update_fn(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adam_dynamic_lr_override_matches_static():
    init_fn, update_fn = adam(lr=123.0)  # static lr should be ignored
    init2, update2 = adam(lr=0.05)
    p1 = p2 = {"w": jnp.full((3,), 2.0)}
    s1, s2 = init_fn(p1), init2(p2)
    g = {"w": jnp.ones(3)}
    u1, _ = update_fn(g, s1, p1, lr_override=0.05)
    u2, _ = update2(g, s2, p2)
    np.testing.assert_allclose(np.asarray(u1["w"]), np.asarray(u2["w"]))


def test_sgd_momentum_and_clip():
    init_fn, update_fn = sgd(lr=0.1, momentum=0.9)
    params = {"w": jnp.ones(2)}
    state = init_fn(params)
    upd, state = update_fn({"w": jnp.ones(2)}, state, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1)
    clipped, norm = clip_by_global_norm({"w": jnp.full((4,), 10.0)}, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["w"])) - 1.0) < 1e-5


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(sched(jnp.asarray(100))) < 0.2


def test_grad_compression_error_feedback_reduces_bias():
    g = jax.random.normal(KEY, (128,)) * 0.01 + 1.0
    err = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    for _ in range(16):
        q, s, err = compress_tree(g, err)
        total_q = total_q + decompress_tree(q, s)
    # time-averaged quantized stream converges to the true gradient
    np.testing.assert_allclose(np.asarray(total_q / 16), np.asarray(g),
                               atol=2e-3)
