"""Sharding rules + a dry-run-lite pass (8 host devices in a subprocess —
exactly the production dryrun.py code path, reduced mesh)."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh  # safe: function, no state
from repro.models import lm as L
from repro.models.sharding import batch_spec, param_specs, spec_for

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_spec_rules():
    m = FakeMesh()
    assert spec_for("segments.dense.attn.wq.w", (36, 4096, 4096), m) == \
        P(None, ("pod", "data"), "model")
    assert spec_for("segments.dense.attn.wo.w", (36, 4096, 4096), m) == \
        P(None, "model", ("pod", "data"))
    assert spec_for("segments.moe.mlp.experts.w_gate", (48, 128, 2048, 768), m) == \
        P(None, "model", ("pod", "data"), None)
    assert spec_for("embed.embedding", (151936, 896), m) == \
        P("model", ("pod", "data"))
    # lm_head: vocab over model
    assert spec_for("lm_head.w", (896, 151936), m) == \
        P(("pod", "data"), "model")
    # non-dividing dims fall back to replication
    assert spec_for("segments.dense.attn.wq.w", (2, 100, 50), m) == P(None, None, None)


def test_param_specs_cover_all_big_leaves():
    m = FakeMesh()
    cfg = get_config("qwen3_8b")
    params = jax.eval_shape(lambda k: L.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, m)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = sum(1 for s in flat_s if any(a is not None for a in s))
    # every matmul weight should be sharded (only norms/biases replicated)
    big = sum(1 for (path, leaf) in flat_p if leaf.size > 1_000_000)
    assert n_sharded >= big


def test_batch_spec_divisibility():
    m = FakeMesh()
    assert batch_spec((256, 4096), m) == P(("pod", "data"), None)
    assert batch_spec((1, 4096), m) == P(None, None)


def test_make_production_mesh_requires_512_devices():
    if len(jax.devices()) < 512:
        with pytest.raises(Exception):
            make_production_mesh(multi_pod=True)


DRYRUN_LITE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.launch import dryrun
from repro.launch.mesh import make_host_mesh
out = {}
mesh = make_host_mesh(model=2, data=2, pod=2)
for arch, shape in [("qwen2_0_5b", "train_4k"), ("rwkv6_1_6b", "decode_32k")]:
    compiled, lowered, info = dryrun.build_cell(arch, shape, mesh=mesh)
    info = dryrun.analyze_cell(compiled, info)
    out[f"{arch}:{shape}"] = {k: info[k] for k in
                              ("bottleneck", "hlo_flops_per_device",
                               "collective_bytes_per_device")}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_lite_multipod_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", DRYRUN_LITE], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for cell, info in out.items():
        assert info["hlo_flops_per_device"] > 0, cell
        assert info["collective_bytes_per_device"] > 0, cell
