"""End-to-end driver tests: checkpoint/restart (fault tolerance) and the
population PBT loop, via the real ``repro.launch.train`` CLI in subprocesses
— the same entry points a cluster launcher would call."""
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _train(args, timeout=480):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        env=ENV, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


@pytest.mark.slow
def test_checkpoint_restart_continues_loss_curve(tmp_path):
    common = ["--arch", "qwen2_0_5b", "--smoke", "--batch", "2",
              "--seq-len", "32", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "10"]
    out1 = _train(common + ["--steps", "20"])
    loss1 = float(re.findall(r"final loss (\d+\.\d+)", out1)[-1])
    # crash-and-restart: second run resumes from the step-19 checkpoint
    out2 = _train(common + ["--steps", "40"])
    assert "resumed from step 19" in out2
    loss2 = float(re.findall(r"final loss (\d+\.\d+)", out2)[-1])
    assert loss2 < loss1  # training continued, not restarted


@pytest.mark.slow
def test_population_pbt_driver(tmp_path):
    out = _train(["--arch", "qwen2_0_5b", "--smoke", "--batch", "2",
                  "--seq-len", "32", "--steps", "20", "--population", "4",
                  "--pbt-interval", "10", "--ckpt-dir", str(tmp_path)])
    # exploit/explore fired twice, reported through the telemetry console
    # sink ([evolve N] parents=[...] ... strategy=PBT)
    assert out.count("[evolve") == 2
    assert out.count("strategy=PBT") == 2
    assert "pop=4" in out
