"""The overlapped acting engine test wall (``repro.rollout.overlap``).

Pins the acceptance properties of the split collect/update pipeline:

  * ``policy_lag=0`` is the PARITY ANCHOR — bitwise-identical trainer
    state, key chain, buffers and env state against the serial fused
    engine, across all four algorithms (the two-program split with the
    serial key discipline must be a pure refactor at lag 0);
  * ``policy_lag=1`` has the declared OFF-BY-ONE property — collect for
    iteration t+1 acts with the params captured BEFORE update t, and
    update t consumes exactly the slot collect t-1 produced;
  * CHUNKED collection (``chunk_steps``) is bitwise-equal to unchunked —
    scanning fixed-size chunks through the ring must insert the same
    transitions with the same key chain;
  * ZERO steady-state recompiles at lag 1 (both programs re-enter their
    caches) and no implicit host transfers post-warmup;
  * ``restore_elastic`` installs the background-AOT executables (the
    resize-time recompile overlaps data movement);
  * telemetry: ``block_every`` emits ``blocks`` dispatch/wait split rows
    that ``tools/report.py`` summarizes and ``--check`` accepts.
"""
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import PopulationConfig
from repro.envs import make
from repro.pop import PopTrainer
from repro.rl import get_algo, make_agent
from repro.rollout import OverlapEngine, RolloutEngine

ALGO_ENV = {"td3": "pendulum", "sac": "pendulum",
            "dqn": "cartpole", "ppo": "cartpole"}


def _build(algo, *, policy_lag=None, chunk_steps=None, size=3, seed=7,
           strategy="pbt", pbt_interval=100, checkpoint_dir=None):
    env = make(ALGO_ENV[algo])
    pcfg = PopulationConfig(
        size=size, strategy=strategy, backend="vectorized",
        num_steps=1 if algo == "ppo" else 2, pbt_interval=pbt_interval,
        fitness_window=10, donate=False,
        hyper_space=get_algo(algo).hyper_space)
    tr = PopTrainer(make_agent(algo, env.spec, hidden=(8, 8)), pcfg,
                    seed=seed, checkpoint_dir=checkpoint_dir)
    kwargs = dict(num_envs=2, collect_steps=8, eval_envs=2, eval_steps=20,
                  policy_lag=policy_lag, chunk_steps=chunk_steps)
    if algo == "ppo":
        tr.attach_rollout(env, batch_size=16, epochs=1, **kwargs)
    else:
        tr.attach_rollout(env, batch_size=16, buffer_capacity=512, **kwargs)
    return tr


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _assert_engines_equal(ta, tb, msg=""):
    _assert_trees_equal(ta.state, tb.state, f"{msg}: population state")
    np.testing.assert_array_equal(np.asarray(ta.key), np.asarray(tb.key),
                                  err_msg=f"{msg}: trainer key chain")
    _assert_trees_equal(ta.rollout.bufs, tb.rollout.bufs,
                        f"{msg}: experience buffers")
    _assert_trees_equal(ta.rollout.vstate, tb.rollout.vstate,
                        f"{msg}: env state")


def _run(tr, iters=5, eval_every=2):
    tr.run_env_loop(iters, eval_every=eval_every)
    return tr


# --------------------------------------------------- lag=0 parity anchor
@pytest.mark.parametrize("algo", sorted(ALGO_ENV))
def test_lag0_bitwise_matches_serial(algo):
    """The two-program split at policy_lag=0 is a pure refactor of the
    serial fused iteration: identical state, keys, buffers, env state."""
    serial = _run(_build(algo))
    assert isinstance(serial.rollout, RolloutEngine)
    assert not isinstance(serial.rollout, OverlapEngine)
    lag0 = _run(_build(algo, policy_lag=0))
    assert isinstance(lag0.rollout, OverlapEngine)
    _assert_engines_equal(serial, lag0, f"{algo} lag0 vs serial")


# ----------------------------------------------------- chunked collection
@pytest.mark.parametrize("algo", ["td3", "ppo"])
def test_chunked_collect_bitwise_matches_unchunked(algo):
    """Scanning collect in fixed-size chunks (bounded memory at thousands
    of envs) must not change a single bit: same key chain, same ring
    positions, same training trajectory."""
    whole = _run(_build(algo))
    chunked = _run(_build(algo, chunk_steps=4))
    _assert_engines_equal(whole, chunked, f"{algo} chunked vs whole")


def test_chunk_steps_must_divide_collect_steps():
    with pytest.raises(ValueError, match="chunk_steps"):
        _build("td3", chunk_steps=3)   # collect_steps=8


# --------------------------------------------------- lag=1 staleness law
@pytest.mark.parametrize("algo", ["td3", "ppo"])
def test_lag1_off_by_one_property(algo):
    """The declared semantics of the overlapped path: collect for t+1 uses
    actors(state_t) captured BEFORE update t ran, and update t consumes
    exactly the slot the previous collect produced."""
    tr = _build(algo, policy_lag=1)
    eng = tr.rollout
    calls = []
    orig = eng._call

    def spy(which, *args):
        out = orig(which, *args)
        calls.append((which, args, out))
        return out

    eng._call = spy
    pre_states = []
    for _ in range(4):
        pre_states.append(tr.state)
        tr.env_iteration()

    # call sequence: prologue collect, then (update, collect) per iteration
    kinds = [c[0] for c in calls]
    assert kinds == ["collect"] + ["update", "collect"] * 4

    collects = [c for c in calls if c[0] == "collect"]
    updates = [c for c in calls if c[0] == "update"]
    for t, up in enumerate(updates):
        # update(t) trains on the slot produced by collect(t-1) — the
        # prologue's slot for t=0 (identity, not value, equality)
        slot_consumed = up[1][2]
        slot_produced = collects[t][2][1]
        assert jax.tree.leaves(slot_consumed)[0] is \
            jax.tree.leaves(slot_produced)[0], f"update {t} wrong slot"
        # update(t) sees state_t...
        _assert_trees_equal(up[1][0], pre_states[t],
                            f"update {t} state")
    for t, co in enumerate(collects[1:]):
        # ...while collect(t+1), dispatched in the SAME iterate() call,
        # acts with the actors of state_t — pre-update params: one behind
        _assert_trees_equal(
            co[1][0], eng.agent.actor_params(pre_states[t]),
            f"collect {t + 1} actor params not one update behind")


def test_lag1_runs_and_trains(tmp_path):
    """End-to-end sanity at lag=1: finite metrics, buffers fill, evolve
    cadence works, export/import drops the in-flight slot cleanly."""
    tr = _build("td3", policy_lag=1, pbt_interval=3)
    tr.run_env_loop(6, eval_every=1)
    assert tr.rollout._pending is not None
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(tr.state))
    state = tr.rollout.export_state()
    tr.rollout.import_state(state)
    assert tr.rollout._pending is None     # restore re-runs the prologue
    tr.run_env_loop(2, eval_every=1)


def test_lag1_validates_lag_values():
    with pytest.raises(ValueError, match="policy_lag"):
        _build("td3", policy_lag=2)


def test_lag1_fused_epoch_unsupported():
    tr = _build("td3", policy_lag=1)
    with pytest.raises(NotImplementedError):
        tr.rollout.build_epoch(epoch_len=4)
    with pytest.raises(NotImplementedError):
        tr.run_env_loop(4, eval_every=0, fused=True)


# ------------------------------------------- steady-state recompiles = 0
def test_lag1_zero_steady_state_recompiles():
    tr = _build("td3", policy_lag=1)
    for _ in range(2):       # warm both programs (prologue + full pipe)
        tr.env_iteration()
    events = []
    unregister = compat.register_compile_listener(
        lambda e, s: events.append(e))
    if unregister is None:
        pytest.skip("no jax.monitoring surface")
    try:
        for _ in range(3):
            tr.env_iteration()
        jax.block_until_ready((tr.state, tr.rollout._pending))
    finally:
        unregister()
    assert events == [], f"steady-state recompiles: {events}"


def test_lag1_no_host_transfers_post_warmup():
    tr = _build("td3", policy_lag=1)
    for _ in range(2):
        tr.env_iteration()
    with jax.transfer_guard("disallow"):
        tr.env_iteration()


# ------------------------------------------------ elastic AOT installing
@pytest.mark.parametrize("policy_lag", [None, 1])
def test_restore_elastic_installs_aot_executables(tmp_path, policy_lag):
    """restore_elastic starts the new topology's compile on a background
    thread while resize_tree moves data; by return the engine must be
    running the AOT executables, and iteration must work."""
    from repro.elastic import restore_elastic

    src = _build("td3", size=3, checkpoint_dir=str(tmp_path))
    src.run_env_loop(3, eval_every=1)
    src.save(blocking=True)

    dst = _build("td3", size=2, policy_lag=policy_lag,
                 checkpoint_dir=str(tmp_path))
    step, lineage = restore_elastic(dst)
    eng = dst.rollout
    if policy_lag is None:
        assert eng._iteration_exec is not eng._iteration, \
            "serial engine still on lazy jit after restore_elastic"
    else:
        assert eng._exec["update"] is not eng._progs["update"], \
            "overlap engine still on lazy jit after restore_elastic"
        assert eng._exec["collect"] is not eng._progs["collect"]
    dst.run_env_loop(2, eval_every=1)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(dst.state))


# ----------------------------------------------- dispatch/block telemetry
def test_block_telemetry_rows_and_report(tmp_path, capsys):
    """run_env_loop(block_every=1) times an explicit block_until_ready per
    iteration into the iter rows' ``blocks`` field; tools/report.py
    summarizes it and --check accepts the file."""
    from repro.telemetry import JSONLSink, RunTelemetry

    path = tmp_path / "run.jsonl"
    tr = _build("td3", policy_lag=1)
    tr.telemetry = RunTelemetry(JSONLSink(path, strict=True))
    tr.run_env_loop(3, eval_every=1, block_every=1)
    tr.telemetry.close()

    rows = [json.loads(line) for line in path.read_text().splitlines()]
    iters = [r for r in rows if r["kind"] == "iter"]
    assert len(iters) == 3
    assert all("blocks" in r and "iterate" in r["blocks"] for r in iters)
    assert all("phases" in r for r in iters)

    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "tools"))
    try:
        import report
    finally:
        sys.path.pop(0)
    blocks = report.block_summary(iters)
    assert "iterate" in blocks
    assert report.check_rows(rows) == []
    report.report(rows)
    out = capsys.readouterr().out
    assert "blocks" in out


def test_block_every_rejects_fused():
    tr = _build("td3")
    with pytest.raises(ValueError, match="block_every"):
        tr.run_env_loop(4, fused=True, block_every=1)
