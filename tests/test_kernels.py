"""Kernel parity wall: property-based sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode against its ``repro.kernels.ref``
oracle over two layers of cases:

  * deterministic seeded sweeps — a seeded RNG draws shapes/dtypes at
    collection time, so the same cases run everywhere, every time (pop=1,
    odd dims, zero grads, lr=0 and other edges are pinned explicitly);
  * hypothesis variants — the same properties under randomized search,
    gated on ``import hypothesis`` (tier-1 CI installs it; the suite stays
    green without it).

The population-batched network applies (``repro.rl.networks.pop_*``) are
checked here too: the jnp fallback must be BITWISE equal to ``vmap`` of the
per-member apply (that equality is what makes ``fused_linear`` a pure
routing decision), and the kernel path — forward and ``custom_vjp``
backward — must match to interpret-mode tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.pop_adam import pop_adam
from repro.kernels.pop_matmul import supports_shapes
from repro.nn.basic import mlp_init, mlp_apply
from repro.rl import networks as nets

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - tier-1 CI installs it
    HAVE_HYPOTHESIS = False

    def given(**kw):         # decoration-time no-ops: the tests under them
        return lambda f: f   # are skipif'd, but must still collect

    settings = given

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")

KEY = jax.random.PRNGKey(0)

TOL = {jnp.float32: dict(atol=2e-4, rtol=2e-4),
       jnp.bfloat16: dict(atol=0.15, rtol=0.1)}

# one seeded generator, drawn at collection: the deterministic layer of the
# property suite (same cases on every machine, no hypothesis needed)
_RNG = np.random.default_rng(20260808)


def _draw_matmul_cases():
    # pinned edges: pop=1, singleton dims, odd dims, block-aligned 128s
    cases = [(1, 1, 1, 1, "none"), (1, 8, 3, 5, "tanh"),
             (3, 7, 5, 9, "relu"), (2, 128, 128, 128, "none"),
             (1, 256, 64, 128, "relu"), (5, 128, 128, 256, "tanh")]
    for _ in range(8):
        cases.append((int(_RNG.integers(1, 7)), int(_RNG.integers(1, 97)),
                      int(_RNG.integers(1, 97)), int(_RNG.integers(1, 97)),
                      str(_RNG.choice(["none", "relu", "tanh"]))))
    return cases


def _matmul_parity(n, b, k, m, act, dtype, *, bias=True):
    ks = jax.random.split(jax.random.fold_in(KEY, n * b * k * m), 3)
    x = jax.random.normal(ks[0], (n, b, k), dtype)
    w = jax.random.normal(ks[1], (n, k, m), dtype) / np.sqrt(k)
    bb = jax.random.normal(ks[2], (n, m), dtype) if bias else None
    y = ops.pop_matmul(x, w, bb, activation=act, interpret=True)
    yr = ref.pop_matmul_ref(x, w, bb, activation=act)
    assert y.shape == (n, b, m) and y.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


@pytest.mark.parametrize("n,b,k,m,act", _draw_matmul_cases())
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pop_matmul_sweep(n, b, k, m, act, dtype):
    _matmul_parity(n, b, k, m, act, dtype)


def test_pop_matmul_no_bias():
    _matmul_parity(2, 16, 8, 8, "relu", jnp.float32, bias=False)


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 6), b=st.integers(1, 64), k=st.integers(1, 64),
       m=st.integers(1, 64), act=st.sampled_from(["none", "relu", "tanh"]),
       bias=st.booleans())
def test_pop_matmul_property(n, b, k, m, act, bias):
    _matmul_parity(n, b, k, m, act, jnp.float32, bias=bias)


def test_supports_shapes():
    """The routing predicate of repro.rl.networks: within-block dims and
    block multiples pass; anything straddling a block boundary is refused
    (the kernel would assert on the tiling)."""
    assert supports_shapes(1, 1, 1)          # everything inside one block
    assert supports_shapes(64, 17, 100)
    assert supports_shapes(256, 128, 384)    # block multiples
    assert not supports_shapes(200, 64, 64)  # 200 > 128, not a multiple
    assert not supports_shapes(64, 130, 64)
    assert not supports_shapes(64, 64, 129)
    assert not supports_shapes(0, 64, 64)    # degenerate


# ------------------------------------------------------------- pop_adam
def _adam_inputs(seed, n, psize, *, zero_grads=False, zero_state=False):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 4)
    params = jax.random.normal(ks[0], (n, psize))
    grads = jnp.zeros((n, psize)) if zero_grads \
        else jax.random.normal(ks[1], (n, psize))
    mu = jnp.zeros((n, psize)) if zero_state \
        else jax.random.normal(ks[2], (n, psize)) * 0.1
    nu = jnp.zeros((n, psize)) if zero_state \
        else jnp.abs(jax.random.normal(ks[3], (n, psize))) * 0.01
    return params, grads, mu, nu


def _adam_parity(seed, n, psize, block, lr, step):
    params, grads, mu, nu = _adam_inputs(seed, n, psize)
    p2, m2, v2 = pop_adam(params, grads, mu, nu, lr, step, block=block,
                          interpret=True)
    pr, mr, vr = ref.pop_adam_ref(params, grads, mu, nu, lr, step)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-6)


def _adam_cases():
    # block clamps to min(block, P) and then P must tile: cover P inside
    # one block (odd P included) and P an exact multiple of the block
    cases = [(1, 1, 32), (1, 128, 32), (2, 64, 64), (3, 257, 512),
             (4, 8192, 4096)]
    for _ in range(5):
        n = int(_RNG.integers(1, 7))
        block = int(2 ** _RNG.integers(5, 12))
        if _RNG.integers(2):
            psize = int(_RNG.integers(1, block + 1))     # P <= block
        else:
            psize = block * int(_RNG.integers(1, 5))     # block multiple
        cases.append((n, psize, block))
    return cases


@pytest.mark.parametrize("n,psize,block", _adam_cases())
@pytest.mark.parametrize("step", [1, 7, 10_000])
def test_pop_adam_sweep(n, psize, block, step):
    lr = jnp.linspace(1e-4, 3e-3, n)
    _adam_parity(n * psize + step, n, psize, block,
                 lr, jnp.asarray(step, jnp.int32))


def test_pop_adam_per_member_step():
    """step may be (N,) — members evolve-cloned mid-run disagree on t."""
    _adam_parity(11, 3, 65, 128, jnp.full((3,), 1e-3),
                 jnp.asarray([1, 5, 900], jnp.int32))


def test_pop_adam_lr_zero_is_identity_on_params():
    params, grads, mu, nu = _adam_inputs(5, 2, 33)
    p2, m2, v2 = pop_adam(params, grads, mu, nu, jnp.zeros((2,)),
                          jnp.asarray(3, jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(params))
    # moments still integrate the gradient
    assert float(jnp.max(jnp.abs(m2 - mu))) > 0


def test_pop_adam_zero_grads_zero_state_is_identity():
    params, grads, mu, nu = _adam_inputs(6, 2, 40, zero_grads=True,
                                         zero_state=True)
    p2, m2, v2 = pop_adam(params, grads, mu, nu, jnp.full((2,), 1e-3),
                          jnp.asarray(1, jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(params))
    assert float(jnp.max(jnp.abs(m2))) == 0
    assert float(jnp.max(jnp.abs(v2))) == 0


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5), raw=st.integers(1, 600),
       block=st.sampled_from([32, 128, 1024]), mult=st.integers(1, 8),
       small=st.booleans(), step=st.integers(1, 10_000),
       scalar_step=st.booleans())
def test_pop_adam_property(n, raw, block, mult, small, step, scalar_step):
    psize = min(raw, block) if small else block * mult
    lr = jnp.linspace(1e-4, 3e-3, n)
    s = jnp.asarray(step, jnp.int32) if scalar_step \
        else jnp.arange(1, n + 1, dtype=jnp.int32) * step
    _adam_parity(seed=step + n + psize, n=n, psize=psize, block=block,
                 lr=lr, step=s)


# ------------------------------------------------------- flash attention
_FLASH_CASES = [(1, 4, 4, 128, 32), (2, 8, 2, 256, 64), (1, 6, 1, 512, 64),
                (1, 1, 1, 128, 16)] + [
    (int(_RNG.integers(1, 3)),) + (lambda g, kv: (g * kv, kv))(
        int(_RNG.integers(1, 4)), int(_RNG.integers(1, 4))) +
    (int(_RNG.choice([128, 256])), int(_RNG.choice([16, 32, 64])))
    for _ in range(4)]


def _flash_parity(b, h, hkv, s, d, dtype, causal=True):
    ks = jax.random.split(jax.random.fold_in(KEY, b * h * s * d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    orf = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **TOL[dtype])


@pytest.mark.parametrize("b,h,hkv,s,d", _FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, s, d, dtype):
    _flash_parity(b, h, hkv, s, d, dtype)


def test_flash_attention_non_causal():
    _flash_parity(1, 2, 2, 128, 32, jnp.float32, causal=False)


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), g=st.integers(1, 3), hkv=st.integers(1, 3),
       s=st.sampled_from([128, 256]), d=st.sampled_from([16, 32, 64]),
       causal=st.booleans())
def test_flash_attention_property(b, g, hkv, s, d, causal):
    _flash_parity(b, g * hkv, hkv, s, d, jnp.float32, causal)


# ------------------------------------------- population-batched applies
def test_pop_linear_jnp_fallback_bitwise_vs_vmap():
    """fused=False lowers to the same dot_general as vmap of the member
    linear — BITWISE.  This equality is the whole fused_linear contract."""
    ks = jax.random.split(KEY, 3)
    n, b, k, m = 4, 9, 7, 11
    p = {"w": jax.random.normal(ks[0], (n, k, m)),
         "b": jax.random.normal(ks[1], (n, m))}
    x = jax.random.normal(ks[2], (n, b, k))
    y = nets.pop_linear_apply(p, x, activation="tanh", fused=False)
    yv = jax.vmap(lambda w, bb, xx: jnp.tanh(xx @ w + bb))(p["w"], p["b"], x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yv))


def test_pop_mlp_jnp_fallback_bitwise_vs_vmap():
    n, b = 3, 6
    params = jax.vmap(lambda k: mlp_init(k, [5, 16, 16, 2]))(
        jax.random.split(KEY, n))
    x = jax.random.normal(jax.random.PRNGKey(3), (n, b, 5))
    y = nets.pop_mlp_apply(params, x, fused=False)
    yv = jax.vmap(mlp_apply)(params, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yv))
    ya = nets.pop_actor_apply(params, x, fused=False)
    np.testing.assert_array_equal(np.asarray(ya),
                                  np.asarray(jnp.tanh(yv)))


@pytest.mark.parametrize("n,b,k,m", [(1, 8, 4, 4), (3, 16, 8, 12),
                                     (2, 128, 128, 128)])
def test_pop_linear_kernel_forward_and_grad(n, b, k, m):
    """The forced-kernel path (interpret off-TPU): forward matches the jnp
    route to tolerance, and jax.grad flows through the custom_vjp with the
    einsum backward (gradients match the fallback's)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 17), 3)
    p = {"w": jax.random.normal(ks[0], (n, k, m)) / np.sqrt(k),
         "b": jax.random.normal(ks[1], (n, m))}
    x = jax.random.normal(ks[2], (n, b, k))
    yf = nets.pop_linear_apply(p, x, activation="tanh", fused=True)
    yj = nets.pop_linear_apply(p, x, activation="tanh", fused=False)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yj),
                               atol=2e-5, rtol=2e-5)

    def loss(params, xx, fused):
        y = nets.pop_linear_apply(params, xx, activation="tanh", fused=fused)
        return jnp.sum(y ** 2)

    gf = jax.grad(loss, argnums=(0, 1))(p, x, True)
    gj = jax.grad(loss, argnums=(0, 1))(p, x, False)
    for a, bb in zip(jax.tree.leaves(gf), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   atol=2e-4, rtol=2e-4)


def test_pop_linear_untileable_shape_falls_back():
    """fused=True on a shape supports_shapes refuses must still work (the
    auto/forced routes fall back to jnp instead of asserting)."""
    n, b, k, m = 2, 200, 64, 64   # 200 straddles the 128 block
    assert not supports_shapes(b, k, m)
    ks = jax.random.split(KEY, 3)
    p = {"w": jax.random.normal(ks[0], (n, k, m)),
         "b": jax.random.normal(ks[1], (n, m))}
    x = jax.random.normal(ks[2], (n, b, k))
    y = nets.pop_linear_apply(p, x, fused=True)
    yj = nets.pop_linear_apply(p, x, fused=False)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yj))


# ----------------------------------------------- recurrent kernels (kept)
@pytest.mark.parametrize("b,h,s,d,chunk", [(1, 2, 64, 8, 16), (2, 3, 128, 16, 32),
                                           (1, 1, 256, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(b, h, s, d, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, h, s, d), dtype) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5 - 2.0)
    u = (jax.random.normal(ks[4], (h, d)) * 0.3)
    s0 = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    y, sf = ops.wkv6(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr, np.float32),
                               **TOL[dtype])
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("b,h,s,p,n,chunk", [(1, 2, 64, 8, 4, 16),
                                             (2, 4, 128, 16, 8, 32),
                                             (1, 1, 256, 64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, h, s, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n), dtype)
    cc = jax.random.normal(ks[4], (b, s, n), dtype)
    h0 = jax.random.normal(ks[5], (b, h, p, n)) * 0.1
    y, sf = ops.ssd(x, dt, a, bb, cc, h0, chunk=chunk, interpret=True)
    yr, sr = ref.ssd_ref(x, dt, a, bb, cc, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr, np.float32),
                               **TOL[dtype])
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr, np.float32),
                               **TOL[dtype])


def test_grad_accum_equivalence():
    """tcfg.grad_accum microbatching == full-batch step (fp32 accumulate)."""
    from repro.configs import get_config, TrainConfig
    from repro.models import lm as L
    cfg = get_config("qwen2_0_5b").smoke()
    params = L.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    outs = {}
    for ga in (1, 4):
        oi, ts = L.make_train_step(cfg, TrainConfig(
            total_steps=10, warmup_steps=0, grad_accum=ga))
        p2, _, m = jax.jit(ts)(params, oi(params), batch, jnp.asarray(1))
        outs[ga] = (float(m["loss"]), p2)
    assert abs(outs[1][0] - outs[4][0]) < 1e-5
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])))
    assert err < 1e-4
