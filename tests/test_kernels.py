"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

TOL = {jnp.float32: dict(atol=2e-4, rtol=2e-4),
       jnp.bfloat16: dict(atol=0.15, rtol=0.1)}


@pytest.mark.parametrize("n,b,k,m", [(2, 64, 32, 64), (5, 128, 128, 256),
                                     (1, 256, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "relu", "tanh"])
def test_pop_matmul_sweep(n, b, k, m, dtype, act):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (n, b, k), dtype)
    w = jax.random.normal(ks[1], (n, k, m), dtype) / np.sqrt(k)
    bias = jax.random.normal(ks[2], (n, m), dtype)
    y = ops.pop_matmul(x, w, bias, activation=act, interpret=True)
    yr = ref.pop_matmul_ref(x, w, bias, activation=act)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 4, 4, 128, 32), (2, 8, 2, 256, 64),
                                         (1, 6, 1, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, s, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    o = ops.flash_attention(q, k, v, interpret=True)
    orf = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **TOL[dtype])


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    o = ops.flash_attention(q, k, v, causal=False, interpret=True)
    orf = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-4)


@pytest.mark.parametrize("b,h,s,d,chunk", [(1, 2, 64, 8, 16), (2, 3, 128, 16, 32),
                                           (1, 1, 256, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(b, h, s, d, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, h, s, d), dtype) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5 - 2.0)
    u = (jax.random.normal(ks[4], (h, d)) * 0.3)
    s0 = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    y, sf = ops.wkv6(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr, np.float32),
                               **TOL[dtype])
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("b,h,s,p,n,chunk", [(1, 2, 64, 8, 4, 16),
                                             (2, 4, 128, 16, 8, 32),
                                             (1, 1, 256, 64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, h, s, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n), dtype)
    cc = jax.random.normal(ks[4], (b, s, n), dtype)
    h0 = jax.random.normal(ks[5], (b, h, p, n)) * 0.1
    y, sf = ops.ssd(x, dt, a, bb, cc, h0, chunk=chunk, interpret=True)
    yr, sr = ref.ssd_ref(x, dt, a, bb, cc, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr, np.float32),
                               **TOL[dtype])
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("n,psize,block", [(2, 64, 64), (4, 8192, 4096),
                                           (1, 128, 32)])
def test_pop_adam_sweep(n, psize, block):
    ks = jax.random.split(KEY, 4)
    params = jax.random.normal(ks[0], (n, psize))
    grads = jax.random.normal(ks[1], (n, psize))
    mu = jax.random.normal(ks[2], (n, psize)) * 0.1
    nu = jnp.abs(jax.random.normal(ks[3], (n, psize))) * 0.01
    lr = jnp.linspace(1e-4, 3e-3, n)
    step = jnp.asarray(7, jnp.int32)
    from repro.kernels.pop_adam import pop_adam
    p2, m2, v2 = pop_adam(params, grads, mu, nu, lr, step, block=block,
                          interpret=True)
    pr, mr, vr = ref.pop_adam_ref(params, grads, mu, nu, lr, step)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-6)


def test_grad_accum_equivalence():
    """tcfg.grad_accum microbatching == full-batch step (fp32 accumulate)."""
    from repro.configs import get_config, TrainConfig
    from repro.models import lm as L
    cfg = get_config("qwen2_0_5b").smoke()
    params = L.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    outs = {}
    for ga in (1, 4):
        oi, ts = L.make_train_step(cfg, TrainConfig(
            total_steps=10, warmup_steps=0, grad_accum=ga))
        p2, _, m = jax.jit(ts)(params, oi(params), batch, jnp.asarray(1))
        outs[ga] = (float(m["loss"]), p2)
    assert abs(outs[1][0] - outs[4][0]) < 1e-5
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])))
    assert err < 1e-4
