"""The fused train–evolve epoch test wall.

``PopTrainer.run_env_loop(fused=True)`` executes whole epochs —
``pbt_interval`` fused iterations + evaluations + the strategy's evolve —
as ONE jitted donated program (``RolloutEngine.build_epoch``).  These tests
pin the three acceptance properties of that fusion:

  * BIT-EXACT against the eager loop — population state, hypers, key
    chain, step count, strategy internals and last fitness, across the
    algorithm registry and the PBT/CEM/DvD strategies (the eager and fused
    paths share one jitted evolve executable, so even CEM's distribution
    refit agrees bitwise);
  * ZERO steady-state recompiles — warm epochs re-enter cached
    executables (``repro.compat.register_compile_listener`` counts);
  * ZERO host round-trips — the warm loop runs under
    ``jax.transfer_guard("disallow")`` (device-to-host stays guarded;
    bookkeeping slices are scope-allowed int uploads only).

Plus the population-level update parity that makes the epoch possible:
``make_population_update`` (the hoisted ``population_adam`` path, with and
without ``fused_linear``) against ``vmap`` of the stock per-member update.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import PopulationConfig
from repro.envs import make
from repro.pop import PopTrainer, SharedCriticAgent
from repro.rl import get_algo, make_agent

ALGO_ENV = {"td3": "pendulum", "sac": "pendulum",
            "dqn": "cartpole", "ppo": "cartpole"}


def _build(algo, strategy, *, fused_adam=True, fused_linear=False,
           backend="vectorized", size=3, pbt_interval=4, fitness_window=10,
           seed=7):
    env = make(ALGO_ENV[algo])
    pcfg = PopulationConfig(
        size=size, strategy=strategy, backend=backend,
        num_steps=1 if algo == "ppo" else 2, pbt_interval=pbt_interval,
        fitness_window=fitness_window, donate=False,
        hyper_space=get_algo(algo).hyper_space,
        fused_adam=fused_adam, fused_linear=fused_linear)
    tr = PopTrainer(make_agent(algo, env.spec, hidden=(8, 8)), pcfg,
                    seed=seed)
    kwargs = dict(num_envs=2, collect_steps=8, eval_envs=2, eval_steps=20)
    if algo == "ppo":
        tr.attach_rollout(env, batch_size=16, epochs=1, **kwargs)
    else:
        tr.attach_rollout(env, batch_size=16, buffer_capacity=512, **kwargs)
    return tr


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _assert_trees_close(a, b, msg="", **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   err_msg=msg, **tol)


def _assert_trainers_equal(ea, fu):
    _assert_trees_equal(ea.state, fu.state, "population state")
    np.testing.assert_array_equal(np.asarray(ea.key), np.asarray(fu.key),
                                  err_msg="trainer key chain")
    assert ea.step_count == fu.step_count
    assert (ea.hypers is None) == (fu.hypers is None)
    if ea.hypers is not None:
        _assert_trees_equal(ea.hypers, fu.hypers, "hypers")
    _assert_trees_equal(ea.strategy.export_state(),
                        fu.strategy.export_state(), "strategy state")
    assert (ea.last_fitness is None) == (fu.last_fitness is None)
    if ea.last_fitness is not None:
        np.testing.assert_array_equal(np.asarray(ea.last_fitness),
                                      np.asarray(fu.last_fitness),
                                      err_msg="last_fitness")
    assert len(ea._window) == len(fu._window)
    for wa, wb in zip(ea._window, fu._window):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb),
                                      err_msg="fitness window")


# ----------------------------------------- population-update parity
@pytest.mark.parametrize("algo", sorted(ALGO_ENV))
def test_population_update_matches_vmap_of_stock(algo):
    """fused_adam=True swaps vmap(stock update) for the module's
    population-level update (optimizer hoisted into population_adam):
    same training trajectory to float tolerance, per-member hypers
    included."""
    a = _build(algo, "pbt", fused_adam=False, pbt_interval=100)
    b = _build(algo, "pbt", fused_adam=True, pbt_interval=100)
    a.run_env_loop(4, eval_every=2)
    b.run_env_loop(4, eval_every=2)
    _assert_trees_close(a.state, b.state, f"{algo} pop-update parity",
                        rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", sorted(ALGO_ENV))
def test_fused_linear_matches_member_linears(algo):
    """fused_linear routes the member forwards through the population-
    batched pop_* applies; off-TPU that is the batched-einsum fallback,
    which lowers to the same dot_general as the vmap — bitwise."""
    a = _build(algo, "pbt", fused_adam=True, pbt_interval=100)
    b = _build(algo, "pbt", fused_adam=True, fused_linear=True,
               pbt_interval=100)
    a.run_env_loop(4, eval_every=2)
    b.run_env_loop(4, eval_every=2)
    _assert_trees_close(a.state, b.state, f"{algo} fused_linear parity",
                        rtol=1e-5, atol=1e-6)


def test_shared_critic_fused_linear_parity():
    """The §4.2 shared-critic update under fused_linear: member policy
    forwards go population-batched, the (axis-free) shared critic stays on
    the plain apply — same update to float tolerance."""
    from repro.core import shared
    key = jax.random.PRNGKey(0)
    n, B, obs, act = 4, 8, 3, 1
    st = shared.init(key, obs, act, n)
    batch = {"obs": jax.random.normal(key, (n, B, obs)),
             "action": jax.random.normal(key, (n, B, act)),
             "reward": jax.random.normal(key, (n, B)),
             "next_obs": jax.random.normal(key, (n, B, obs)),
             "done": jnp.zeros((n, B))}
    s0, m0 = jax.jit(shared.make_shared_critic_update(fused_adam=True))(
        st, batch, None)
    s1, m1 = jax.jit(shared.make_shared_critic_update(
        fused_adam=True, fused_linear=True))(st, batch, None)
    _assert_trees_close(s0, s1, "shared-critic fused_linear",
                        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m0["critic_loss"]),
                               float(m1["critic_loss"]), rtol=1e-5)


# ------------------------------------------------ epoch bit-exactness
@pytest.mark.parametrize("algo,strategy",
                         [(a, s) for a in sorted(ALGO_ENV)
                          for s in ("pbt", "cem", "dvd")])
def test_fused_epoch_bitwise_vs_eager(algo, strategy):
    """Two epochs (8 iters, evolve every 4, eval every 2) through the
    fused path reproduce the eager loop BITWISE — state, hypers, key
    chain, strategy internals, last fitness, window — over the full
    algorithm registry x strategy grid (CEM's distribution refit agrees
    bitwise because eager and fused share ONE jitted evolve
    executable)."""
    ea = _build(algo, strategy)
    fu = _build(algo, strategy)
    ea.run_env_loop(8, eval_every=2)
    fu.run_env_loop(8, eval_every=2, fused=True)
    _assert_trainers_equal(ea, fu)


def test_fused_epoch_bitwise_non_evolving():
    """Below the evolve cadence the epoch is just fused iterations +
    evaluations; the fitness window must fill with the same device rows."""
    ea = _build("td3", "none")
    fu = _build("td3", "none")
    ea.run_env_loop(4, eval_every=2)
    fu.run_env_loop(4, eval_every=2, fused=True)
    _assert_trainers_equal(ea, fu)
    assert len(fu._window) == 2


def test_fused_epoch_resumes_across_calls():
    """Back-to-back fused calls chain exactly like one longer eager run
    (the epoch cache re-enters the compiled executable)."""
    ea = _build("td3", "pbt")
    fu = _build("td3", "pbt")
    ea.run_env_loop(16, eval_every=2)
    fu.run_env_loop(8, eval_every=2, fused=True)
    fu.run_env_loop(8, eval_every=2, fused=True)
    _assert_trainers_equal(ea, fu)


# ------------------------------------- recompiles and host transfers
def test_fused_epoch_zero_steady_state_recompiles():
    tr = _build("td3", "pbt")
    tr.run_env_loop(8, eval_every=2, fused=True)   # warm: traces epoch+evolve
    events = []
    cancel = compat.register_compile_listener(
        lambda info: events.append(info))
    try:
        tr.run_env_loop(8, eval_every=2, fused=True)
    finally:
        cancel()
    assert not events, f"steady-state recompiles: {events}"


def test_fused_epoch_no_host_round_trips():
    """The acceptance property: a warm fused epoch — including the evolve
    and all host-side bookkeeping — runs under transfer_guard('disallow').
    The trainer scope-allows its python-int bookkeeping uploads; anything
    fetching device values back to the host would still raise."""
    tr = _build("td3", "pbt")
    tr.run_env_loop(8, eval_every=2, fused=True)
    with jax.transfer_guard("disallow"):
        metrics, stats = tr.run_env_loop(8, eval_every=2, fused=True)
    assert isinstance(metrics["critic_loss"], jax.Array)
    assert np.isfinite(np.asarray(metrics["critic_loss"])).all()


# ------------------------------------------------- alignment guards
def test_fused_epoch_alignment_errors():
    tr = _build("td3", "pbt")
    with pytest.raises(ValueError, match="multiple of pbt_interval"):
        tr.run_env_loop(6, eval_every=2, fused=True)
    with pytest.raises(ValueError, match="divide pbt_interval"):
        tr.run_env_loop(8, eval_every=3, fused=True)
    tr2 = _build("td3", "pbt", fitness_window=1)
    with pytest.raises(ValueError, match="overflow fitness_window"):
        tr2.run_env_loop(8, eval_every=2, fused=True)
    tr3 = _build("td3", "pbt")
    tr3.report_fitness(jnp.zeros(3))
    with pytest.raises(ValueError, match="non-empty"):
        tr3.run_env_loop(8, eval_every=2, fused=True)


def test_fused_epoch_misaligned_step_count_errors():
    tr = _build("td3", "pbt")
    tr.run_env_loop(1, eval_every=0)          # eager, no window -> no evolve
    with pytest.raises(ValueError, match="not epoch-aligned"):
        tr.run_env_loop(8, eval_every=2, fused=True)


def test_fused_epoch_boundary_crossing_errors():
    tr = _build("td3", "pbt")
    tr.run_env_loop(3, eval_every=0)          # step_count = 3
    with pytest.raises(ValueError, match="crosses an evolve boundary"):
        tr.run_env_loop(2, eval_every=2, fused=True)


# ------------------------------------------------------ islands (8 dev)
needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="islands fused-epoch tests want 8 (fake) devices; run with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@needs_devices
def test_fused_epoch_bitwise_on_islands():
    """The fused epoch shard_maps over the 'pop' mesh axis unchanged: the
    islands backend reproduces its own eager loop's TRAINING path bitwise —
    population state, key chain, step count.

    Evaluation fitness is compared structurally, not bitwise: on a multi-
    device runtime XLA re-fuses the evaluator inlined into the epoch
    program at ~1 ULP vs the eager standalone executable (measured 4e-9 on
    the policy forward, replicated params included), and twenty steps of
    chaotic pendulum dynamics amplify a ULP to O(1) episode returns.  The
    shard_mapped update path has a pinned program boundary, so the state
    trajectory stays bitwise — which is what the fusion must preserve."""
    ea = _build("td3", "none", backend="islands", size=4)
    fu = _build("td3", "none", backend="islands", size=4)
    ea.run_env_loop(4, eval_every=2)
    fu.run_env_loop(4, eval_every=2, fused=True)
    _assert_trees_equal(ea.state, fu.state, "islands population state")
    np.testing.assert_array_equal(np.asarray(ea.key), np.asarray(fu.key),
                                  err_msg="islands key chain")
    assert ea.step_count == fu.step_count
    assert len(ea._window) == len(fu._window) == 2
    for wa, wb in zip(ea._window, fu._window):
        assert np.asarray(wb).shape == np.asarray(wa).shape
        assert np.isfinite(np.asarray(wb)).all()


@needs_devices
def test_fused_epoch_evolves_on_islands():
    """The full train–evolve epoch runs sharded: evolve fires on device,
    the population state stays partitioned over the 'pop' mesh axis, and
    warm epochs re-enter the cached executable (zero recompiles)."""
    tr = _build("td3", "pbt", backend="islands", size=4)
    tr.run_env_loop(8, eval_every=2, fused=True)
    assert tr.last_fitness is not None
    assert np.isfinite(np.asarray(tr.last_fitness)).all()
    events = []
    cancel = compat.register_compile_listener(
        lambda info: events.append(info))
    try:
        tr.run_env_loop(8, eval_every=2, fused=True)
    finally:
        cancel()
    assert not events, f"islands steady-state recompiles: {events}"
    for leaf in jax.tree.leaves(tr.state):
        assert np.isfinite(np.asarray(leaf)).all()
        assert "pop" in str(leaf.sharding), (
            f"fused epoch lost the 'pop' sharding: {leaf.sharding}")


@needs_devices
def test_islands_fused_update_matches_vectorized():
    """Sharding decides WHERE members update, never what they compute: the
    population-level fused_adam + fused_linear update under shard_map
    tracks the single-device vectorized backend on identical batches (the
    fused companion of test_elastic's islands-numerics check)."""
    from repro.pop import ModuleAgent
    from repro.rl import td3
    from repro.configs.base import HyperSpace
    n, bsz, obs, act = 8, 16, 3, 1
    space = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),))
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    batch = {"obs": jax.random.normal(ks[0], (n, bsz, obs)),
             "action": jax.random.uniform(ks[1], (n, bsz, act),
                                          minval=-1, maxval=1),
             "reward": jax.random.normal(ks[2], (n, bsz)),
             "next_obs": jax.random.normal(ks[3], (n, bsz, obs)),
             "done": jnp.zeros((n, bsz))}
    out = {}
    for backend in ("vectorized", "islands"):
        pcfg = PopulationConfig(size=n, strategy="pbt", backend=backend,
                                hyper_space=space, donate=False,
                                pbt_interval=0, fused_adam=True,
                                fused_linear=True)
        tr = PopTrainer(ModuleAgent(td3, obs, act), pcfg, seed=0)
        for _ in range(2):
            tr.step(batch)
        out[backend] = jax.device_get(tr.state)
    _assert_trees_close(out["vectorized"], out["islands"],
                        "islands vs vectorized fused update",
                        rtol=1e-5, atol=1e-5)
