"""Decode-vs-full-sequence logit consistency for every architecture family.

The strongest end-to-end correctness check in the suite: running the model
token-by-token through `serve_step` (KV caches / WKV states / SSD states /
conv states threaded through the scan) must reproduce the full-sequence
forward pass exactly (up to fp accumulation).  For MoE archs the capacity
factor is raised so routing drops cannot differ between the two paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm as L

KEY = jax.random.PRNGKey(0)

ARCHS = ["qwen2_0_5b", "qwen3_8b", "gemma_7b", "qwen3_moe_30b_a3b",
         "deepseek_v2_lite_16b", "rwkv6_1_6b", "zamba2_7b", "musicgen_medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).smoke()
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    params = L.init_params(KEY, cfg)
    b, s = 2, 8
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model))
    full, _, _ = L.forward(params, cfg, batch)

    serve = jax.jit(L.make_serve_step(cfg))
    state = L.init_decode_state(cfg, b, 16)
    errs = []
    for t in range(s):
        step_batch = {"tokens": batch["tokens"][:, t:t + 1]}
        if cfg.frontend == "audio_frames":
            step_batch["embeds"] = batch["embeds"][:, t:t + 1]
        logits, state = serve(params, step_batch, state,
                              jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full[:, t]))))
    assert max(errs) < 5e-4, f"{arch}: decode diverges from full ({max(errs)})"
