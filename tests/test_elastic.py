"""``repro.elastic`` — island layouts, elastic resize, checkpoint re-layout.

Three layers:
  * pure math (layout planning, resize index maps, the bugfix guards) runs
    in-process, device-count-agnostic;
  * the full save -> resize -> resume round-trip for a ``PopTrainer`` with
    an attached ``RolloutEngine`` runs in-process too (re-layout is
    topology-agnostic: shapes, not devices — these pass at 1 device
    locally and at 8 on the tier-2 CI job's faked topology alike);
  * device-count CHANGES (8 -> 4 fake host devices) and the islands
    backend's cross-device numerics run in subprocesses with their own
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` (it must be set
    before jax initializes, so the parent's count can't be reused).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HyperSpace, PopulationConfig
from repro.elastic import (IslandLayout, grow_population, plan_layout,
                          plan_resize, resize_tree, restore_elastic,
                          shrink_population)
from repro.elastic.layout import plan_grid
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPACE = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),),
                   uniform=(("explore_noise", 0.0, 0.5),))


# ------------------------------------------------------------- layout math

def test_plan_grid_shapes_and_fallback_warning():
    for n, model, want in [(512, 16, (32, 16)), (256, 16, (16, 16)),
                           (4, 4, (1, 4))]:
        shape, axes = plan_grid(n, preferred_model=model)
        assert shape == want and axes == ("data", "model"), (n, model)
    # preferred_model does not divide the device count: warn, don't
    # silently hand back a shrunken model axis
    for n, model, want in [(6, 16, (3, 2)), (8, 16, (1, 8))]:
        with pytest.warns(UserWarning, match="does not divide"):
            shape, _ = plan_grid(n, preferred_model=model)
        assert shape == want, (n, model)
    # nothing fits: the degenerate (n, 1) data-only grid, loudly
    with pytest.warns(UserWarning, match="pure data parallelism"):
        shape, _ = plan_grid(7, preferred_model=16)
    assert shape == (7, 1)


def test_plan_layout_paper_regime_and_validation():
    # the paper's §5.1 setup: 80 agents on 4 accelerators = 4 islands x 20
    lay = plan_layout(4, 80)
    assert (lay.islands, lay.members_per_island, lay.data) == (4, 20, 1)
    # more devices than members: spend the rest on the data axis
    lay = plan_layout(8, 4)
    assert (lay.islands, lay.data, lay.model) == (4, 2, 1)
    # coprime population: one island, pure data parallelism inside it
    lay = plan_layout(4, 3)
    assert (lay.islands, lay.data) == (1, 4)
    with pytest.warns(UserWarning, match="does not divide"):
        lay = plan_layout(6, 8, preferred_model=4)
    assert lay.model == 2 and lay.islands == 1 and lay.data == 3
    with pytest.raises(ValueError, match="does not tile"):
        IslandLayout(devices=4, islands=2, data=3, model=1, population=4)
    with pytest.raises(ValueError, match="whole islands"):
        IslandLayout(devices=4, islands=4, data=1, model=1, population=6)


def test_plan_layout_explicit_devices():
    """Heterogeneous hosts: an explicit ``devices=`` sequence pins both the
    device COUNT and the ORDER the mesh walks them in (islands follow the
    caller's sequence, not enumeration order) — pure math until .mesh."""
    lay = plan_layout(0, 8, devices=[3, 2, 1, 0])
    assert lay.devices == 4 and lay.device_ids == (3, 2, 1, 0)
    assert (lay.islands, lay.data, lay.model) == (4, 1, 1)
    # matching num_devices is allowed; a disagreeing one is not
    assert plan_layout(4, 8, devices=[3, 2, 1, 0]) == lay
    with pytest.raises(ValueError, match="disagrees"):
        plan_layout(3, 4, devices=[0, 1])
    with pytest.raises(ValueError, match="duplicate"):
        IslandLayout(devices=2, islands=2, data=1, model=1, population=4,
                     device_ids=(0, 0))
    with pytest.raises(ValueError, match="device ids for a layout"):
        IslandLayout(devices=2, islands=2, data=1, model=1, population=4,
                     device_ids=(0,))

    # jax Device objects are accepted, and the built mesh follows the
    # given order exactly (reversed when this process has > 1 device)
    devs = jax.devices()
    chosen = list(reversed(devs)) if len(devs) > 1 else devs[:1]
    lay2 = plan_layout(0, len(chosen), devices=chosen)
    assert lay2.device_ids == tuple(d.id for d in chosen)
    assert list(lay2.mesh.devices.flat) == chosen
    # ids absent from this process fail at mesh-build time, loudly
    bad = plan_layout(0, 1, devices=[max(d.id for d in devs) + 7])
    with pytest.raises(ValueError, match="not present"):
        bad.mesh


# ------------------------------------------------------------ resize math

def test_shrink_population_keeps_fittest():
    pop = {"w": jnp.arange(8.0)[:, None] * jnp.ones((8, 3))}
    fitness = jnp.asarray([3., 9., 1., 7., 5., 0., 8., 2.])
    small, keep = shrink_population(pop, fitness, 4)
    assert small["w"].shape == (4, 3)
    assert set(np.asarray(keep).tolist()) == {1, 3, 4, 6}  # top-4


def test_shrink_to_zero_raises():
    pop = {"w": jnp.ones((4, 3))}
    with pytest.raises(ValueError, match="new_size"):
        shrink_population(pop, jnp.arange(4.0), 0)
    with pytest.raises(ValueError, match="at least 1"):
        plan_resize(4, 0)


def test_grow_population_clones_fittest_survivors_stay_bit_exact():
    pop = {"w": jnp.arange(4.0)[:, None] * jnp.ones((4, 3))}
    fitness = jnp.asarray([1.0, 9.0, 5.0, 3.0])
    big, parents = grow_population(pop, fitness, 7)
    assert big["w"].shape == (7, 3)
    np.testing.assert_array_equal(np.asarray(big["w"][:4]),
                                  np.asarray(pop["w"]))       # survivors
    assert np.asarray(parents)[4:].tolist() == [1, 2, 3]      # fittest refill


def test_grow_population_sizes_from_fitness_not_first_leaf():
    # a shared-critic-style tree whose FIRST leaf has no population axis:
    # the old size must come from the fitness length, never the leaf
    tree = {"critic": jnp.ones((3, 3)), "w": jnp.arange(4.0)[:, None]}
    fitness = jnp.asarray([1.0, 9.0, 5.0, 3.0])
    big, parents = grow_population(tree, fitness, 6)
    assert big["w"].shape == (6, 1)
    assert big["critic"].shape == (3, 3)        # untouched
    assert np.asarray(parents)[4:].tolist() == [1, 2]
    with pytest.raises(ValueError, match="fitness"):
        grow_population(tree, None, 6)


def test_resize_tree_skips_non_population_leaves():
    tree = {"stacked": jnp.ones((4, 2)), "shared_critic": jnp.ones((3, 3)),
            "scalar": jnp.ones(())}
    out = resize_tree(tree, 4, np.array([0, 2]))
    assert out["stacked"].shape == (2, 2)
    assert out["shared_critic"].shape == (3, 3)  # no population axis: kept
    assert out["scalar"].shape == ()


# ---------------------------------------- trainer round-trip (in-process)

def _build(n, ckpt_dir, backend="vectorized"):
    pcfg = PopulationConfig(size=n, strategy="pbt", backend=backend,
                            num_steps=2, pbt_interval=0, hyper_space=SPACE,
                            donate=False)
    env = make("pendulum")
    tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=0, checkpoint_dir=ckpt_dir)
    tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=16,
                      buffer_capacity=256, eval_envs=1)
    return tr


@pytest.mark.parametrize("new_n,expect_lineage", [
    (2, [0, 2]),              # shrink: fitness [3,1,4,2] keeps members 0, 2
    (6, [0, 1, 2, 3, 2, 0]),  # grow: survivors + fittest clones (2 then 0)
])
def test_restore_elastic_roundtrip_preserves_members(tmp_path, new_n,
                                                     expect_lineage):
    tr = _build(4, tmp_path)
    for _ in range(3):
        tr.env_iteration()
    tr.report_fitness(np.array([3.0, 1.0, 4.0, 2.0]))
    tr.save(blocking=True)
    saved = jax.device_get((tr.state, tr.hypers,
                            tr.rollout.bufs, tr.rollout.vstate))

    tr2 = _build(new_n, tmp_path)
    step, lineage = restore_elastic(tr2)
    assert step == 2 and np.asarray(lineage).tolist() == expect_lineage

    parents = np.asarray(lineage)
    state, hypers, bufs, vstate = saved
    # surviving members' training state: bit-exact
    for a, b in zip(jax.tree.leaves(jax.device_get(tr2.state)),
                    jax.tree.leaves(state)):
        np.testing.assert_array_equal(a, b[parents])
    # replay-buffer contents + counters ride along, gathered the same way
    np.testing.assert_array_equal(np.asarray(tr2.rollout.bufs.total),
                                  bufs.total[parents])
    np.testing.assert_array_equal(np.asarray(tr2.rollout.bufs.data["obs"]),
                                  bufs.data["obs"][parents])
    # env states + episode accounting too
    np.testing.assert_array_equal(np.asarray(tr2.rollout.vstate.obs),
                                  vstate.obs[parents])
    np.testing.assert_array_equal(
        np.asarray(tr2.rollout.vstate.completed_return_sum),
        vstate.completed_return_sum[parents])
    # per-member hypers follow their member
    np.testing.assert_array_equal(np.asarray(tr2.hypers["actor_lr"]),
                                  hypers["actor_lr"][parents])
    # and training continues from the restored state
    _, _, did = tr2.env_iteration()
    assert bool(did)


def test_same_size_resume_restores_rollout_state(tmp_path):
    tr = _build(3, tmp_path)
    for _ in range(2):
        tr.env_iteration()
    tr.save(blocking=True)
    tr2 = _build(3, tmp_path)
    assert tr2.resume() == 1
    np.testing.assert_array_equal(np.asarray(tr2.rollout.bufs.total),
                                  np.asarray(jax.device_get(tr.rollout.bufs.total)))
    np.testing.assert_array_equal(np.asarray(tr2.rollout.vstate.obs),
                                  np.asarray(jax.device_get(tr.rollout.vstate.obs)))


def test_mismatched_resume_points_to_elastic(tmp_path):
    tr = _build(4, tmp_path)
    tr.env_iteration()
    tr.save(blocking=True)
    tr2 = _build(2, tmp_path)
    with pytest.raises(ValueError, match="restore_elastic"):
        tr2.resume()


def test_restore_elastic_empty_dir_raises(tmp_path):
    tr = _build(2, tmp_path)
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        restore_elastic(tr)


# ----------------------------------------- device-count changes (subproc)

def _run_subprocess(script, devices, *argv, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", script, *map(str, argv)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


ROUNDTRIP = """
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import HyperSpace, PopulationConfig
from repro.elastic import plan_layout, restore_elastic
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3

phase, ckpt, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
env = make("pendulum")
space = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),))
pcfg = PopulationConfig(size=n, strategy="pbt", backend="islands",
                        num_steps=2, pbt_interval=0, hyper_space=space,
                        donate=False)
layout = plan_layout(len(jax.devices()), n)
tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                pcfg, seed=0, layout=layout, checkpoint_dir=ckpt)
tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=16,
                  buffer_capacity=256, eval_envs=1)
digest = lambda t: [np.asarray(x).astype(np.float64).sum().item()
                    for x in jax.tree.leaves(jax.device_get(t))]
if phase == "save":
    for _ in range(3):
        tr.env_iteration()
    tr.report_fitness(np.array([3.0, 1.0, 4.0, 2.0]))
    tr.save(blocking=True)
    parents = [0, 2] if n > 2 else [0, 1]
    keep = np.asarray(parents)
    sub = lambda t: jax.tree.map(
        lambda x: x[keep] if (x.ndim >= 1 and x.shape[0] == n) else x,
        jax.device_get(t))
    print(json.dumps({
        "devices": len(jax.devices()),
        "islands": layout.islands,
        "actors_kept": digest(sub(tr.actors)),
        "buf_total_kept": np.asarray(tr.rollout.bufs.total)[keep].tolist(),
        "buf_obs_kept": digest(sub(tr.rollout.bufs.data["obs"])),
        "ep_return_kept": digest(sub(tr.rollout.vstate.completed_return_sum)),
    }))
else:
    step, lineage = restore_elastic(tr)
    restored = {
        "devices": len(jax.devices()),
        "islands": layout.islands,
        "step": step,
        "lineage": np.asarray(lineage).tolist(),
        "actors_kept": digest(tr.actors),
        "buf_total_kept": np.asarray(
            jax.device_get(tr.rollout.bufs.total)).tolist(),
        "buf_obs_kept": digest(tr.rollout.bufs.data["obs"]),
        "ep_return_kept": digest(tr.rollout.vstate.completed_return_sum),
    }
    _, _, did = tr.env_iteration()   # training continues on the new mesh
    restored["continues"] = bool(did)
    print(json.dumps(restored))
"""


@pytest.mark.slow
def test_relayout_across_device_counts_preserves_members(tmp_path):
    """Save 4 members on 8 fake devices; resume 2 of them on 4 devices:
    surviving members' params, replay buffers and episode stats intact
    (bit-exact digests), and the fused iteration keeps training."""
    out8 = _run_subprocess(ROUNDTRIP, 8, "save", tmp_path, 4)
    assert (out8["devices"], out8["islands"]) == (8, 4)
    out4 = _run_subprocess(ROUNDTRIP, 4, "load", tmp_path, 2)
    assert (out4["devices"], out4["islands"]) == (4, 2)
    assert out4["step"] == 2 and out4["lineage"] == [0, 2]
    assert out4["continues"]
    # fitness [3,1,4,2] keeps members 0 and 2; digests must match exactly
    for k in ("actors_kept", "buf_total_kept", "buf_obs_kept",
              "ep_return_kept"):
        assert out4[k] == out8[k], k


ISLANDS_NUMERICS = """
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import HyperSpace, PopulationConfig
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3

N, B, OBS, ACT = 8, 16, 3, 1
space = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),))
key = jax.random.PRNGKey(1)
ks = jax.random.split(key, 5)
batch = {"obs": jax.random.normal(ks[0], (N, B, OBS)),
         "action": jax.random.uniform(ks[1], (N, B, ACT), minval=-1, maxval=1),
         "reward": jax.random.normal(ks[2], (N, B)),
         "next_obs": jax.random.normal(ks[3], (N, B, OBS)),
         "done": jnp.zeros((N, B))}
out = {}
for backend in ("vectorized", "islands"):
    pcfg = PopulationConfig(size=N, strategy="pbt", backend=backend,
                            hyper_space=space, donate=False, pbt_interval=0)
    tr = PopTrainer(ModuleAgent(td3, OBS, ACT), pcfg, seed=0)
    for i in range(2):
        tr.step(batch)
    out[backend] = jax.device_get(tr.state)
err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
          for a, b in zip(jax.tree.leaves(out["vectorized"]),
                          jax.tree.leaves(out["islands"])))
print(json.dumps({"max_err": err, "devices": len(jax.devices())}))
"""


@pytest.mark.slow
def test_islands_backend_matches_vectorized_numerics():
    """On an 8-fake-device mesh the islands backend (shard_map over the
    population axis) must produce the same member updates as the single-
    device vectorized backend — sharding decides where, never what."""
    out = _run_subprocess(ISLANDS_NUMERICS, 8)
    assert out["devices"] == 8
    assert out["max_err"] < 1e-5, out


def test_islands_backend_runs_in_process(tmp_path):
    """backend="islands" is registered through the ordinary registry and
    auto-plans a layout for whatever devices this process has (1 island on
    the plain 1-device run; 2 on the tier-2 8-fake-device CI job) — the
    one-line config swap the other backends promise."""
    import math
    tr = _build(2, tmp_path, backend="islands")
    assert tr.layout is not None
    assert tr.layout.islands == math.gcd(2, len(jax.devices()))
    _, _, did = tr.env_iteration()
    metrics, _, _ = tr.env_iteration()
    assert np.isfinite(float(metrics["critic_loss"][0]))
