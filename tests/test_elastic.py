"""Elastic re-layout: checkpoint on one mesh, resume on a smaller one."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.elastic import plan_mesh, shrink_population

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_plan_mesh_shapes():
    # helper is pure math until make_mesh; just check the chosen grid
    for n, model, want in [(512, 16, (32, 16)), (256, 16, (16, 16)),
                           (8, 16, (1, 8)), (6, 16, (3, 2)), (1, 16, (1, 1))]:
        m = model
        while m > 1 and (n % m or n // m < 1):
            m //= 2
        assert (n // m, m) == want, (n, model)


def test_shrink_population_keeps_fittest():
    pop = {"w": jnp.arange(8.0)[:, None] * jnp.ones((8, 3))}
    fitness = jnp.asarray([3., 9., 1., 7., 5., 0., 8., 2.])
    small, keep = shrink_population(pop, fitness, 4)
    assert small["w"].shape == (4, 3)
    assert set(keep.tolist()) == {1, 3, 4, 6}  # top-4 by fitness


SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from repro import compat
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, TrainConfig
from repro.launch.elastic import plan_mesh, relayout
from repro.models import lm as L

phase, ckpt_dir = sys.argv[1], sys.argv[2]
cfg = get_config("qwen2_0_5b").smoke()
mesh = plan_mesh(len(jax.devices()), preferred_model=2)
mgr = CheckpointManager(ckpt_dir, keep=2)
key = jax.random.PRNGKey(0)
template = L.init_params(key, cfg)
if phase == "save":
    params = relayout(template, mesh)
    mgr.save(10, params, {"loss": 1.23})
    print(json.dumps({"mesh": dict(mesh.shape),
                      "ok": True}))
else:
    params, extra = mgr.restore(template)
    params = relayout(params, mesh)   # new (smaller) mesh
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    with compat.set_mesh(mesh):
        loss, _ = L.lm_loss(params, cfg, batch)
    print(json.dumps({"mesh": dict(mesh.shape), "step": extra["step"],
                      "loss": float(loss), "ok": bool(np.isfinite(float(loss)))}))
"""


@pytest.mark.slow
def test_checkpoint_relayout_across_device_counts(tmp_path):
    def run(devices, phase):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
        r = subprocess.run([sys.executable, "-c", SCRIPT % devices, phase,
                            str(tmp_path)], env=env, capture_output=True,
                           text=True, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        return json.loads(r.stdout.strip().splitlines()[-1])

    out1 = run(8, "save")          # "cluster" of 8 devices
    assert out1["ok"]
    out2 = run(4, "load")          # half the nodes survive
    assert out2["ok"] and out2["step"] == 10
    assert out2["mesh"] == {"data": 2, "model": 2}
