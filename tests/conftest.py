import os
import sys

# Smoke tests and benches must see ONE device; only launch/dryrun.py forces
# 512 placeholder devices (per DESIGN.md) — never set that flag here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
