"""repro.rollout acceptance: collector equivalence vs a python-loop reference,
on-device episode stats vs offline returns, evaluator determinism, the
terminal-observation contract (no cross-episode bootstrapping), empty-buffer
gating of the fused iteration, and the two new env scenarios."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PopulationConfig
from repro.data import buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import dqn, td3
from repro.rollout import (Collector, Evaluator, VecEnv, episode_stats,
                           exploration_policy)

KEY = jax.random.PRNGKey(0)


def _stacked_actors(env, n, key=KEY):
    return jax.vmap(lambda k: td3.init(
        k, env.spec.obs_dim, env.spec.act_dim).actor)(jax.random.split(key, n))


# --------------------------------------------------------------- collector
def test_collector_matches_python_loop():
    """The scan'd+vmapped collector reproduces a per-member python loop with
    the same key: booleans and key-chaining exactly, floats to ~1 ulp (XLA
    fuses the MLP policy differently under the member vmap, so bitwise
    equality across the two execution paths is not guaranteed)."""
    env = make("pendulum")
    venv = VecEnv(env, 3)
    n, T = 2, 7
    actors = _stacked_actors(env, n)
    policy = exploration_policy(td3)
    col = Collector(venv, policy)
    k_init, k_col = jax.random.split(jax.random.PRNGKey(1))
    vstate = col.init(k_init, n)
    _, traj = col.collect(actors, vstate, k_col, T)

    member_keys = jax.random.split(k_col, n)
    for i in range(n):
        actor_i = jax.tree.map(lambda x: x[i], actors)
        vs = jax.tree.map(lambda x: x[i], vstate)
        k = member_keys[i]
        for t in range(T):
            k, ka = jax.random.split(k)
            a = policy(actor_i, vs.obs, ka, None)
            vs, trans = venv.step(vs, a)
            for name, ref in trans.items():
                ref = np.asarray(ref)
                got = np.asarray(traj[name][i]).reshape(
                    (T, venv.num_envs) + ref.shape[1:])[t]
                if ref.dtype.kind == "f":
                    np.testing.assert_allclose(
                        got, ref, rtol=1e-6, atol=1e-6,
                        err_msg=f"{name} member {i} step {t}")
                else:
                    np.testing.assert_array_equal(
                        got, ref, err_msg=f"{name} member {i} step {t}")


def test_collector_uses_member_hyper_noise():
    env = make("pendulum")
    venv = VecEnv(env, 2)
    n = 2
    # identical actors + identical env keys: trajectories can only differ
    # through the per-member exploration-noise hyperparameter
    one = td3.init(KEY, env.spec.obs_dim, env.spec.act_dim).actor
    actors = jax.tree.map(lambda x: jnp.stack([x, x]), one)
    col = Collector(venv, exploration_policy(td3))
    vs0 = jax.tree.map(lambda x: jnp.stack([x, x]),
                       venv.reset(jax.random.PRNGKey(7)))
    hypers = {"explore_noise": jnp.asarray([0.0, 1.0])}
    k = jax.random.PRNGKey(8)
    keys = jax.random.split(k, n)
    same_keys = jnp.stack([keys[0], keys[0]])

    def collect_with(ks):
        def member(actor, mvs, mk, mh):
            def body(carry, _):
                vs, kk = carry
                kk, ka = jax.random.split(kk)
                a = col.policy_fn(actor, vs.obs, ka, mh)
                vs, trans = venv.step(vs, a)
                return (vs, kk), trans
            (_, _), tr = jax.lax.scan(body, (mvs, mk), None, length=4)
            return tr
        return jax.vmap(member)(actors, vs0, ks, hypers)

    traj = collect_with(same_keys)
    a0, a1 = np.asarray(traj["action"][0]), np.asarray(traj["action"][1])
    assert not np.array_equal(a0, a1)  # noise=1.0 member explores
    # and the zero-noise member acts exactly deterministically
    det = td3.policy(one, np.asarray(traj["obs"][0][0]), None)
    np.testing.assert_allclose(np.asarray(a0[0]), np.asarray(det),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- episode stats
def test_episode_stats_match_offline_returns():
    env = make("cartpole")
    E, T = 4, 80
    venv = VecEnv(env, E)
    vs = venv.reset(KEY)
    k = jax.random.PRNGKey(2)
    rewards, dones = [], []
    for _ in range(T):
        k, ka = jax.random.split(k)
        actions = jax.random.randint(ka, (E,), 0, 2)
        vs, trans = venv.step(vs, actions)
        rewards.append(np.asarray(trans["reward"]))
        dones.append(np.asarray(trans["done"]))
    rewards, dones = np.stack(rewards), np.stack(dones)

    total_eps, total_ret, total_len = 0, 0.0, 0
    for e in range(E):
        ret, length = 0.0, 0
        for t in range(T):
            ret += rewards[t, e]
            length += 1
            if dones[t, e]:
                total_eps += 1
                total_ret += ret
                total_len += length
                ret, length = 0.0, 0
    assert total_eps > 0  # random cartpole fails well within 80 steps
    stats = episode_stats(vs)
    assert int(stats["episodes"]) == total_eps
    np.testing.assert_allclose(float(stats["mean_return"]),
                               total_ret / total_eps, rtol=1e-5)
    np.testing.assert_allclose(float(stats["mean_length"]),
                               total_len / total_eps, rtol=1e-5)


# -------------------------------------------------------------- evaluator
def test_evaluator_fitness_deterministic_across_jit_vmap():
    env = make("pendulum")
    n = 3
    actors = _stacked_actors(env, n, jax.random.PRNGKey(4))
    ev = Evaluator(env, exploration_policy(td3), num_envs=2, num_steps=40)
    f1 = ev.evaluate(actors, KEY)
    f2 = ev.evaluate(actors, KEY)
    assert f1.shape == (n,)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    # eager per-member reference (no jit, no member vmap)
    keys = jax.random.split(KEY, n)
    for i in range(n):
        ref = ev._member_eval(jax.tree.map(lambda x: x[i], actors), keys[i])
        np.testing.assert_allclose(float(f1[i]), float(ref),
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------- terminal observation regression
def test_vecenv_terminal_obs_not_reset_obs():
    env = make("cartpole")
    venv = VecEnv(env, 1)
    vs = venv.reset(KEY)
    transitions = []
    for _ in range(80):  # constant push -> pole falls fast
        vs, trans = venv.step(vs, jnp.ones((1,), jnp.int32))
        transitions.append(jax.tree.map(lambda x: np.asarray(x)[0], trans))
    dones = [float(tr["done"]) for tr in transitions]
    assert 1.0 in dones
    i = dones.index(1.0)
    term = transitions[i]["next_obs"]
    # stored next_obs is the PRE-reset terminal state (out of bounds), not
    # the freshly-reset obs (uniform in [-0.05, 0.05])
    assert abs(term[0]) > 2.4 or abs(term[2]) > 0.2095
    # the next transition starts the new episode from a reset obs
    assert np.all(np.abs(transitions[i + 1]["obs"]) <= 0.05 + 1e-7)
    # within an episode, next_obs chains exactly into the next obs
    for t in range(i):
        np.testing.assert_array_equal(transitions[t]["next_obs"],
                                      transitions[t + 1]["obs"])


def test_core_rollout_no_cross_episode_bootstrapping():
    env = make("cartpole")
    policy = lambda p, o, k: jnp.ones((), jnp.int32)
    traj = jax.jit(lambda k: rollout(env, policy, None, k, 80))(KEY)
    done = np.asarray(traj["done"])
    obs = np.asarray(traj["obs"])
    nxt = np.asarray(traj["next_obs"])
    idx = np.nonzero(done)[0]
    assert idx.size > 0
    for t in idx:
        if t + 1 < done.shape[0]:
            # new episode starts from a reset observation, so no transition
            # links episode k's terminal state to episode k+1
            assert np.all(np.abs(obs[t + 1]) <= 0.05 + 1e-7)
    for t in range(done.shape[0] - 1):
        if not done[t]:
            np.testing.assert_array_equal(nxt[t], obs[t + 1])


# ------------------------------------------------------ empty-buffer guard
def test_buffer_sample_empty_raises_eagerly():
    buf = buffer_init(16, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="empty buffer"):
        buffer_sample(buf, KEY, 4)


def test_fused_loop_gates_updates_on_can_sample():
    env = make("pendulum")
    pcfg = PopulationConfig(size=2, strategy="none", num_steps=2,
                            donate=False)
    tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=0)
    tr.attach_rollout(env, num_envs=2, collect_steps=4, batch_size=64,
                      buffer_capacity=256, eval_envs=1, eval_steps=10)
    before = jax.tree.map(np.asarray, tr.actors)
    metrics, _, did = tr.env_iteration()  # 8 transitions < batch_size 64
    assert not bool(did)
    assert all(np.all(np.asarray(v) == 0) for v in metrics.values())
    jax.tree.map(np.testing.assert_array_equal, before,
                 jax.tree.map(np.asarray, tr.actors))
    did_any = False
    for _ in range(8):  # 8 more iterations x 8 transitions -> 72 total
        metrics, _, did = tr.env_iteration()
        did_any = did_any or bool(did)
    assert did_any
    changed = jax.tree.leaves(jax.tree.map(
        lambda a, b: np.any(a != np.asarray(b)), before, tr.actors))
    assert any(changed)


def test_fused_offpolicy_iteration_no_transfers_with_live_sink(tmp_path):
    """The telemetry hard constraint: with a live JSONL sink attached and
    per-iteration rows being recorded, the warm fused off-policy iteration
    still runs under transfer_guard('disallow') — phase timers are host
    wall-clock around dispatch and the sink's worker thread (to which the
    thread-local guard does not extend) is the only place metric bytes
    leave the device."""
    from repro.telemetry import JSONLSink, RunTelemetry

    env = make("pendulum")
    tel = RunTelemetry(JSONLSink(tmp_path / "telemetry.jsonl", strict=True))
    pcfg = PopulationConfig(size=2, strategy="none", num_steps=2,
                            donate=False)
    tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=0, telemetry=tel)
    tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=8,
                      buffer_capacity=64, eval_envs=1, eval_steps=5)
    tr.env_iteration()   # compile outside the guard
    with jax.transfer_guard("disallow"):
        metrics, stats, did = tr.env_iteration()
        # exactly what run_env_loop does each iteration, device values
        # passed raw — must not sync on this (guarded) thread
        tel.record_iteration(0, metrics=metrics, stats=stats,
                             did_update=did)
    tel.close()
    import importlib.util
    from pathlib import Path
    spec = importlib.util.spec_from_file_location(
        "report", Path(__file__).resolve().parents[1] / "tools/report.py")
    report = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(report)
    rows = report.load_rows(tmp_path / "telemetry.jsonl")
    assert report.check_rows(rows) == []
    (it,) = [r for r in rows if r["kind"] == "iter"]
    assert it["phases"]["iterate"] > 0
    assert np.isfinite(it["metrics"]["critic_loss"]).all()


# ----------------------------------------------------------- new scenarios
def test_new_envs_step_shapes_and_vmap():
    for name in ("mountain_car", "acrobot"):
        env = make(name)
        state, obs = env.reset(KEY)
        assert obs.shape == (env.spec.obs_dim,)
        action = (jnp.zeros((), jnp.int32) if env.spec.discrete
                  else jnp.zeros((env.spec.act_dim,)))
        state, obs, reward, done, trunc = env.step(state, action)
        assert obs.shape == (env.spec.obs_dim,)
        assert np.isfinite(float(reward))
        keys = jax.random.split(KEY, 4)
        states, obs = jax.vmap(env.reset)(keys)
        actions = (jnp.zeros((4,), jnp.int32) if env.spec.discrete
                   else jnp.zeros((4, env.spec.act_dim)))
        states, obs, rew, done, trunc = jax.vmap(env.step)(states, actions)
        assert obs.shape == (4, env.spec.obs_dim) and rew.shape == (4,)


def test_mountain_car_goal_terminates_with_bonus():
    env = make("mountain_car")
    state, _ = env.reset(KEY)
    state = dict(state, pos=jnp.asarray(0.449), vel=jnp.asarray(0.07))
    _, _, reward, done, truncated = env.step(state, jnp.ones((1,)))
    assert bool(done) and not bool(truncated) and float(reward) > 90


def test_time_limit_is_truncation_not_termination():
    """Pendulum episodes end at t=200 by TRUNCATION: the episode resets but
    the stored transition must keep done=0 so TD targets bootstrap through
    the time limit (a time-out is not a terminal state)."""
    env = make("pendulum")
    venv = VecEnv(env, 1)
    vs = venv.reset(KEY)
    dones = []
    for _ in range(201):
        vs, trans = venv.step(vs, jnp.zeros((1, 1)))
        dones.append(float(np.asarray(trans["done"])[0]))
    assert all(d == 0.0 for d in dones)        # never a bootstrap cut ...
    assert int(vs.completed_episodes[0]) == 1  # ... yet the episode ended
    # and the env-level step reports the split explicitly at step 200
    state, _ = env.reset(KEY)
    done = truncated = None
    for _ in range(199):
        state, _, _, done, truncated = env.step(state, jnp.zeros((1,)))
    assert not bool(done)
    state, _, _, done, truncated = env.step(state, jnp.zeros((1,)))
    assert bool(done) and bool(truncated)


def test_acrobot_dqn_fused_path():
    env = make("acrobot")
    pcfg = PopulationConfig(size=2, strategy="none", num_steps=2,
                            donate=False)
    tr = PopTrainer(ModuleAgent(dqn, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=3)
    tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=8,
                      buffer_capacity=256, eval_envs=1, eval_steps=20)
    metrics, stats, did = tr.env_iteration()
    assert bool(did)
    assert np.isfinite(np.asarray(metrics["loss"])).all()
    fitness = tr.evaluate_fitness()
    assert fitness.shape == (2,)
    assert np.isfinite(np.asarray(fitness)).all()
