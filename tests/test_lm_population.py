"""LM population training: backend parity, fused population-Adam bitwise
equivalence, grad accumulation, model-sharded islands, elastic checkpoint
resize, and PBT lineage replay through ``tools/report.py``.

The acceptance surface of the LM-in-the-hot-path work: LMAgent runs through
the SAME backend registry as the RL agents, and the hoisted
``repro.optim.population_adam`` step is bitwise-equal to stock
optax-under-vmap on the fp32 ``rwkv6_test`` config.

The islands test needs 8 (fake) devices — CI's tier-2 ``lm`` job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under the tier-1
single-device run it skips.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.configs.base import HyperSpace, PopulationConfig
from repro.pop import LMAgent, PopTrainer, make_update
from repro.telemetry import JSONLSink, RunTelemetry

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import report  # noqa: E402

CFG = get_config("rwkv6_test")
TCFG = TrainConfig(total_steps=50, warmup_steps=5, lr=1e-3,
                   weight_decay=0.1)
N = 3


def _pop_state(agent, n=N, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return jax.vmap(agent.init)(keys)


def _batch(n=N, b=2, s=32, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (n, b, s),
                                0, CFG.vocab_size)
    return {"tokens": tokens}


def _hypers(n=N):
    return {"lr_scale": jnp.linspace(0.5, 2.0, n),
            "weight_decay": jnp.linspace(0.01, 0.2, n),
            "warmup_frac": jnp.linspace(0.05, 0.2, n)}


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state.params)]


# ------------------------------------------------------- backend parity
@pytest.mark.parametrize("hypers", [None, "pbt"], ids=["plain", "hypers"])
def test_vectorized_matches_sequential(hypers):
    agent = LMAgent(CFG, TCFG)
    h = _hypers() if hypers else None
    state0, batch = _pop_state(agent), _batch()
    vec = make_update(agent, "vectorized", donate=False)
    seq = make_update(agent, "sequential", donate=False)
    sv, mv = vec(state0, batch, h)
    ss, ms = seq(state0, batch, h)
    np.testing.assert_allclose(np.asarray(mv["loss"]),
                               np.asarray(ms["loss"]), rtol=2e-5)
    for a, b in zip(_leaves(sv), _leaves(ss)):
        np.testing.assert_allclose(a, b, atol=2e-5)


# ------------------------------------------- fused population-Adam parity
@pytest.mark.parametrize("hypers", [None, "pbt"], ids=["plain", "hypers"])
def test_fused_adam_bitwise_equals_stock(hypers):
    h = _hypers() if hypers else None
    stock = LMAgent(CFG, TCFG)
    fused = LMAgent(CFG, TCFG, fused_adam=True)
    state0, batch = _pop_state(stock), _batch()
    up_stock = make_update(stock, "vectorized", donate=False)
    up_fused = make_update(fused, "vectorized", donate=False)
    # two chained steps so second-step state (m, v, step counter) matters
    s1, m1 = up_stock(state0, batch, h)
    s2, m2 = up_fused(state0, batch, h)
    assert np.array_equal(np.asarray(m1["loss"]), np.asarray(m2["loss"]))
    b2 = _batch(seed=2)
    s1, m1 = up_stock(s1, b2, h)
    s2, m2 = up_fused(s2, b2, h)
    assert np.array_equal(np.asarray(m1["loss"]), np.asarray(m2["loss"]))
    for a, b in zip(_leaves(s1), _leaves(s2)):
        assert a.dtype == b.dtype
        assert np.array_equal(a, b), "fused pop-Adam diverged bitwise"


# ------------------------------------------------------- grad accumulation
def test_grad_accum_matches_single_pass():
    from repro.models import lm as L
    b, s, accum = 4, 32, 4
    params = L.init_params(jax.random.PRNGKey(0), CFG)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s),
                                          0, CFG.vocab_size)}
    outs = {}
    for accum in (1, 4):
        tcfg = TCFG.replace(grad_accum=accum) \
            if hasattr(TCFG, "replace") else \
            TrainConfig(total_steps=50, warmup_steps=5, lr=1e-3,
                        weight_decay=0.1, grad_accum=accum)
        opt_init, train_step = L.make_train_step(CFG, tcfg)
        p2, _, metrics = jax.jit(train_step)(
            params, opt_init(params), batch, jnp.zeros((), jnp.int32))
        outs[accum] = (p2, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]),
                    jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# --------------------------------------------- model-sharded islands (8 dev)
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="islands layout test needs 8 (fake) devices")
def test_islands_model_sharded_matches_vectorized():
    from repro.elastic import plan_layout
    n = 4
    layout = plan_layout(8, n, preferred_model=2)
    assert layout.model == 2 and layout.islands * layout.data == 4
    agent = LMAgent(CFG, TCFG)
    assert agent.model_sharded_params
    state0, batch, h = _pop_state(agent, n), _batch(n), _hypers(n)

    vec = make_update(agent, "vectorized", donate=False)
    sv, mv = vec(state0, batch, h)

    placed = layout.place(state0, model_rules=True)
    isl = make_update(agent, "islands", donate=False, mesh=layout.mesh)
    si, mi = isl(placed, batch, h)

    np.testing.assert_allclose(np.asarray(mv["loss"]),
                               np.asarray(mi["loss"]), rtol=2e-5)
    for a, b in zip(_leaves(sv), _leaves(si)):
        np.testing.assert_allclose(a, b, atol=2e-5)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="islands layout test needs 8 (fake) devices")
def test_islands_trainer_end_to_end():
    pcfg = PopulationConfig(
        size=4, strategy="pbt", backend="islands", donate=False,
        pbt_interval=2, fitness_window=2,
        hyper_space=HyperSpace(
            log_uniform=(("lr_scale", 0.1, 10.0),
                         ("weight_decay", 1e-3, 0.3)),
            uniform=(("warmup_frac", 0.01, 0.25),)))
    from repro.elastic import plan_layout
    tr = PopTrainer(LMAgent(CFG, TCFG), pcfg, seed=0,
                    layout=plan_layout(8, 4, preferred_model=2))
    losses = []
    for i in range(4):
        metrics, _ = tr.step(_batch(4, seed=i))
        losses.append(np.asarray(metrics["loss"]))
    assert all(np.all(np.isfinite(l)) for l in losses)
    assert set(tr.hypers) == {"lr_scale", "weight_decay", "warmup_frac"}


# --------------------------------------------- elastic checkpoint resize
def test_checkpoint_restore_elastic_resize(tmp_path):
    from repro.elastic.relayout import restore_elastic
    space = HyperSpace(log_uniform=(("lr_scale", 0.1, 10.0),),
                       uniform=(("warmup_frac", 0.01, 0.25),))
    pcfg = PopulationConfig(size=4, strategy="pbt", donate=False,
                            pbt_interval=2, fitness_window=2,
                            hyper_space=space)
    tr = PopTrainer(LMAgent(CFG, TCFG), pcfg, seed=0,
                    checkpoint_dir=str(tmp_path))
    for i in range(3):
        tr.step(_batch(4, seed=i))
    tr.save(blocking=True)

    pcfg2 = PopulationConfig(size=2, strategy="pbt", donate=False,
                             pbt_interval=2, fitness_window=2,
                             hyper_space=space)
    tr2 = PopTrainer(LMAgent(CFG, TCFG), pcfg2, seed=1,
                     checkpoint_dir=str(tmp_path))
    step, lineage = restore_elastic(tr2)
    assert step == 2 and len(lineage) == 2  # save() records step_count - 1
    # restored members carry the checkpointed params of their parents
    src = {i: np.asarray(jax.tree.leaves(tr.state.params)[0][int(p)])
           for i, p in enumerate(lineage)}
    dst = np.asarray(jax.tree.leaves(tr2.state.params)[0])
    for i, p in src.items():
        assert np.array_equal(dst[i], p)
    metrics, _ = tr2.step(_batch(2, seed=9))
    assert np.all(np.isfinite(np.asarray(metrics["loss"])))


# ------------------------------------------------ PBT lineage via report.py
def test_lm_pbt_lineage_replays_through_report(tmp_path):
    log = tmp_path / "telemetry.jsonl"
    pcfg = PopulationConfig(
        size=4, strategy="pbt", donate=False, pbt_interval=2,
        fitness_window=2,
        hyper_space=HyperSpace(
            log_uniform=(("lr_scale", 0.1, 10.0),
                         ("weight_decay", 1e-3, 0.3)),
            uniform=(("warmup_frac", 0.01, 0.25),)))
    tel = RunTelemetry(JSONLSink(log, strict=True),
                       meta={"arch": "rwkv6_test"})
    tr = PopTrainer(LMAgent(CFG, TCFG), pcfg, seed=0, telemetry=tel)
    tr.tokens_per_step = 2 * 32
    for i in range(6):
        tr.step(_batch(4, seed=i))
    tel.close()

    rows = report.load_rows(log)
    assert report.check_rows(rows) == []
    evolves = [r for r in rows if r["kind"] == "evolve"]
    assert [e["step"] for e in evolves] == [2, 4, 6]
    roots, children, current = report.lineage_tree(rows)
    assert len(roots) == 4 and set(current) == set(range(4))
    # hyper trajectories carry the LM tuning set end to end
    traj = report.hyper_trajectories(rows)
    assert {"lr_scale", "weight_decay", "warmup_frac"} <= set(traj)
    # dispatch-rate throughput lands in the iter rows (first iter has no
    # previous dispatch timestamp, so >= 4 of 6)
    iters = [r for r in rows if r["kind"] == "iter"]
    with_tps = [r for r in iters if "tokens_per_sec_per_member" in r]
    assert len(with_tps) >= 4
    assert all(r["tokens_per_sec_per_member"] > 0 for r in with_tps)
