"""Unit tests for the nn substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import basic
from repro.nn.attention import (gqa_apply, gqa_init, gqa_init_cache,
                                mla_apply, mla_init, mla_init_cache,
                                sdpa, sdpa_chunked)
from repro.nn.moe import moe_apply, moe_init
from repro.nn.rotary import apply_rope
from repro.nn.rwkv6 import wkv6_chunked, wkv6_scan
from repro.nn.mamba2 import ssd_chunked, ssd_scan

KEY = jax.random.PRNGKey(0)


def test_rmsnorm_unit_scale():
    p = basic.rmsnorm_init(16)
    x = jax.random.normal(KEY, (4, 16)) * 10
    y = basic.rmsnorm_apply(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_moments():
    p = basic.layernorm_init(32)
    x = jax.random.normal(KEY, (8, 32)) * 3 + 5
    y = basic.layernorm_apply(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(KEY, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m))
        kn = apply_rope(k, jnp.full((1, 1), n))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


def test_sdpa_chunked_matches_full():
    q = jax.random.normal(KEY, (2, 256, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    o1 = sdpa(q, k, v, pos, pos, causal=True, scale=32 ** -0.5)
    o2 = sdpa_chunked(q, k, v, pos, pos, causal=True, scale=32 ** -0.5,
                      chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_gqa_decode_matches_full():
    cfg = dict(num_heads=4, num_kv_heads=2, head_dim=16)
    p = gqa_init(KEY, d_model=32, qkv_bias=True, qk_norm=True, **cfg)
    b, s = 2, 10
    x = jax.random.normal(KEY, (b, s, 32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full, _ = gqa_apply(p, x, pos, **cfg)
    cache = gqa_init_cache(b, 16, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = gqa_apply(p, x[:, t:t + 1], pos[:, t:t + 1], **cfg,
                             cache=cache, cache_index=t)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-4)


def test_mla_decode_matches_full():
    kw = dict(num_heads=4, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=4,
              v_dim=8)
    p = mla_init(KEY, d_model=32, **kw)
    b, s = 2, 6
    x = jax.random.normal(KEY, (b, s, 32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full, _ = mla_apply(p, x, pos, **kw)
    cache = mla_init_cache(b, 8, 16, 4, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = mla_apply(p, x[:, t:t + 1], pos[:, t:t + 1], **kw,
                             cache=cache, cache_index=t)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-4)


def test_moe_routes_to_topk_and_balances():
    p = moe_init(KEY, d_model=16, d_expert=32, num_experts=4, num_shared=1)
    x = jax.random.normal(KEY, (2, 32, 16))
    out, aux = moe_apply(p, x, num_experts=4, top_k=2, capacity_factor=8.0,
                         group_size=64)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss is minimized (==1) under perfectly uniform routing
    assert float(aux) >= 0.99


def test_moe_capacity_drops_are_residual_passthrough():
    p = moe_init(KEY, d_model=16, d_expert=32, num_experts=4)
    x = jax.random.normal(KEY, (1, 16, 16))
    out_tight, _ = moe_apply(p, x, num_experts=4, top_k=2,
                             capacity_factor=0.25, group_size=16)
    assert np.all(np.isfinite(np.asarray(out_tight)))


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_wkv6_chunked_equals_scan(chunk):
    b, s, h, d = 2, 64, 2, 8
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, d)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, d)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    st = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    y1, s1 = wkv6_scan(r, k, v, lw, u, st)
    y2, s2 = wkv6_chunked(r, k, v, lw, u, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


@pytest.mark.parametrize("chunk", [16, 64])
def test_ssd_chunked_equals_scan(chunk):
    b, s, h, p, n = 2, 64, 2, 8, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    h0 = jax.random.normal(ks[5], (b, h, p, n)) * 0.1
    y1, s1 = ssd_scan(x, dt, a, bb, cc, h0)
    y2, s2 = ssd_chunked(x, dt, a, bb, cc, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
