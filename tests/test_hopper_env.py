"""hopper2d physics pinned against an independent numpy integrator.

The env is written as closed-form jnp math precisely so this file can
re-derive every force term in pure numpy — from the same module-level
constant tables, but none of the jax code — and require the two
integrators to agree to float32 tolerance over multiple control steps.
Plus the env-contract battery every registered env gets: spec shapes,
vmapped reset/step, auto-reset truncation, determinism, stability, and a
rollout-engine smoke run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make
from repro.envs.hopper2d import (_CONTACTS, _H2D, _JOINTS, _REST_POS,
                                 _hopper2d_reset, _hopper2d_step)


# ------------------------------------------------- numpy reference model
def _np_rot(th, off):
    c, s = np.cos(th), np.sin(th)
    lx, lz = off
    return np.array([c * lx - s * lz, s * lx + c * lz])


def _np_point_vel(vel, om, r):
    return vel + om * np.array([-r[1], r[0]])


def _np_cross2(r, f):
    return r[0] * f[1] - r[1] * f[0]


def _np_forces(pos, th, vel, om, action):
    m = np.array(_H2D["mass"])
    f = np.zeros((4, 2))
    f[:, 1] -= _H2D["gravity"] * m
    tau = np.zeros(4)
    for j, (p, ra, c, rb, lo, hi) in enumerate(_JOINTS):
        wa = _np_rot(th[p], ra)
        wb = _np_rot(th[c], rb)
        dx = (pos[p] + wa) - (pos[c] + wb)
        dv = (_np_point_vel(vel[p], om[p], wa)
              - _np_point_vel(vel[c], om[c], wb))
        fj = _H2D["joint_k"] * dx + _H2D["joint_c"] * dv
        f[c] += fj
        f[p] -= fj
        tau[c] += _np_cross2(wb, fj)
        tau[p] += _np_cross2(wa, -fj)
        rel = th[c] - th[p]
        tj = (_H2D["torque"][j] * action[j]
              - _H2D["rot_c"] * (om[c] - om[p])
              - _H2D["limit_k"] * (max(rel - hi, 0.0) + min(rel - lo, 0.0)))
        tau[c] += tj
        tau[p] -= tj
    for b, off in _CONTACTS:
        r = _np_rot(th[b], off)
        p_w = pos[b] + r
        v_w = _np_point_vel(vel[b], om[b], r)
        pen = max(-p_w[1], 0.0)
        if pen > 0.0:
            fn = max(_H2D["contact_k"] * pen
                     - _H2D["contact_c"] * v_w[1], 0.0)
            ft = (-_H2D["friction"] * fn
                  * np.tanh(v_w[0] / _H2D["v_smooth"]))
            fc = np.array([ft, fn])
            f[b] += fc
            tau[b] += _np_cross2(r, fc)
    return f, tau


def _np_control_step(pos, th, vel, om, action):
    """One control step: SUBSTEPS semi-implicit Euler substeps, float64
    numpy throughout (the jnp side is float32 — tolerance absorbs it)."""
    m = np.array(_H2D["mass"])
    L = np.array(_H2D["length"])
    inertia = m * L ** 2 / 12.0
    dt = _H2D["dt"]
    a = np.clip(np.asarray(action, np.float64), -1.0, 1.0)
    for _ in range(_H2D["substeps"]):
        f, tau = _np_forces(pos, th, vel, om, a)
        vel = vel + dt * f / m[:, None]
        om = om + dt * tau / inertia
        pos = pos + dt * vel
        th = th + dt * om
    return pos, th, vel, om


# -------------------------------------------------------- integrator pin
@pytest.mark.parametrize("action", [
    np.zeros(3),
    np.array([0.7, -0.4, 0.9]),
    np.array([-1.0, 1.0, -1.0]),
])
def test_integrator_matches_numpy_reference(action):
    """3 control steps (15 substeps) from a post-reset state must agree
    with the independent float64 numpy integrator to f32 tolerance."""
    state, _ = _hopper2d_reset(jax.random.PRNGKey(3))
    pos = np.asarray(state["pos"], np.float64)
    th = np.asarray(state["th"], np.float64)
    vel = np.asarray(state["vel"], np.float64)
    om = np.asarray(state["om"], np.float64)
    for step in range(3):
        state, _, _, _ = _hopper2d_step(state, jnp.asarray(action,
                                                           jnp.float32))
        pos, th, vel, om = _np_control_step(pos, th, vel, om, action)
        for name, jx, ref in (("pos", state["pos"], pos),
                              ("th", state["th"], th),
                              ("vel", state["vel"], vel),
                              ("om", state["om"], om)):
            np.testing.assert_allclose(
                np.asarray(jx), ref, rtol=2e-4, atol=2e-4,
                err_msg=f"{name} diverged at control step {step}")


def test_reward_is_forward_progress():
    state, _ = _hopper2d_reset(jax.random.PRNGKey(0))
    a = jnp.zeros(3)
    new, _, reward, _ = _hopper2d_step(state, a)
    fwd = (new["pos"][0, 0] - state["pos"][0, 0]) / (
        _H2D["dt"] * _H2D["substeps"])
    np.testing.assert_allclose(float(reward), float(fwd) + 1.0, rtol=1e-5)


def test_termination_on_fallen_torso():
    state, _ = _hopper2d_reset(jax.random.PRNGKey(0))
    fallen = dict(state, pos=state["pos"].at[0, 1].set(0.5))
    _, _, _, term = _hopper2d_step(fallen, jnp.zeros(3))
    assert bool(term)
    tipped = dict(state, th=state["th"].at[0].set(1.5))
    _, _, _, term = _hopper2d_step(tipped, jnp.zeros(3))
    assert bool(term)


# ---------------------------------------------------------- env contract
def test_registry_spec_and_shapes():
    env = make("hopper2d")
    assert env.spec.obs_dim == 11 and env.spec.act_dim == 3
    assert not env.spec.discrete
    assert env.spec.episode_length == 400
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (11,)
    state, obs, reward, done, info = env.step(state, jnp.zeros(3))
    assert obs.shape == (11,) and reward.shape == () and done.shape == ()


def test_vmapped_reset_and_step():
    env = make("hopper2d")
    keys = jax.random.split(jax.random.PRNGKey(0), 16)
    state, obs = jax.vmap(env.reset)(keys)
    assert obs.shape == (16, 11)
    actions = jax.random.uniform(jax.random.PRNGKey(1), (16, 3),
                                 minval=-1, maxval=1)
    state, obs, reward, done, info = jax.vmap(env.step)(state, actions)
    assert obs.shape == (16, 11) and reward.shape == (16,)
    assert np.isfinite(np.asarray(obs)).all()


def test_determinism():
    env = make("hopper2d")
    outs = []
    for _ in range(2):
        state, obs = env.reset(jax.random.PRNGKey(5))
        for i in range(10):
            state, obs, reward, done, _ = env.step(
                state, jnp.sin(jnp.arange(3) + i))
        outs.append((np.asarray(obs), float(reward)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]


def test_stability_under_random_policy():
    """200 random-torque control steps stay finite and physically bounded
    (no spring blow-up), and the auto-reset keeps episodes alive."""
    env = make("hopper2d")
    state, obs = env.reset(jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(9)

    @jax.jit
    def roll(state, obs, key):
        def body(carry, _):
            state, obs, key = carry
            key, ka = jax.random.split(key)
            a = jax.random.uniform(ka, (3,), minval=-1, maxval=1)
            state, obs, reward, done, _ = env.step(state, a)
            return (state, obs, key), (obs, reward)

        return jax.lax.scan(body, (state, obs, key), None, length=200)

    (state, obs, _), (all_obs, rewards) = roll(state, obs, key)
    assert np.isfinite(np.asarray(all_obs)).all()
    assert np.isfinite(np.asarray(rewards)).all()
    assert np.abs(np.asarray(all_obs)).max() < 100.0


def test_stands_under_zero_action():
    """With zero torques from rest the hopper must keep standing: the
    joint springs hold the articulation against gravity, so across 300
    control steps the torso stays above the termination height and below
    launch height — a lightly-damped bounce on the leg springs is fine
    (the contact is a penalty spring), collapse or blow-up is not."""
    env = make("hopper2d")
    state, obs = env.reset(jax.random.PRNGKey(11))

    @jax.jit
    def roll(state):
        def body(s, _):
            s, _, _, _, _ = env.step(s, jnp.zeros(3))
            return s, s["pos"][0, 1]

        return jax.lax.scan(body, state, None, length=300)

    state, torso_z = roll(state)
    z = np.asarray(torso_z)
    assert z.min() > _H2D["z_min"] and z.max() < 1.4
    assert np.abs(np.asarray(state["vel"])).max() < 5.0


def test_rollout_engine_smoke():
    """The physics tier plugs into the full fused engine: two td3
    iterations on hopper2d produce finite params and metrics."""
    from repro.configs.base import PopulationConfig
    from repro.pop import PopTrainer
    from repro.rl import make_agent

    env = make("hopper2d")
    pcfg = PopulationConfig(size=2, strategy="none", backend="vectorized",
                            num_steps=1, donate=False)
    tr = PopTrainer(make_agent("td3", env.spec, hidden=(8, 8)), pcfg,
                    seed=0)
    tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=16,
                      buffer_capacity=256, eval_envs=1, eval_steps=10)
    for _ in range(2):
        metrics, stats, did = tr.env_iteration()
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(tr.state))
