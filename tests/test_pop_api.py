"""Tests for the unified ``repro.pop`` API (Agent / Strategy / Backend /
PopTrainer) — the acceptance surface of the API redesign:

  * one code path for every population size (no ``n == 1`` branching at any
    call site, asserted against the consumer sources);
  * strategy and backend are one-line config swaps;
  * the fitness window is capped; chained metrics are windowed means;
  * checkpoint/resume round-trips state + hypers + step.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HyperSpace, PopulationConfig
from repro.core.vectorize import chain_steps
from repro.pop import (CEM, DvD, LMAgent, ModuleAgent, NoEvolution, PBT,
                       PopTrainer, SharedCriticAgent, make_strategy,
                       make_update)
from repro.rl import td3

KEY = jax.random.PRNGKey(0)
N, B, OBS, ACT = 4, 8, 3, 2
SPACE = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),
                                ("critic_lr", 3e-5, 3e-3)))
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _batch(key, n=N):
    ks = jax.random.split(key, 5)
    return {
        "obs": jax.random.normal(ks[0], (n, B, OBS)),
        "action": jax.random.uniform(ks[1], (n, B, ACT), minval=-1, maxval=1),
        "reward": jax.random.normal(ks[2], (n, B)),
        "next_obs": jax.random.normal(ks[3], (n, B, OBS)),
        "done": jnp.zeros((n, B)),
    }


def _trainer(n=N, strategy="pbt", backend="vectorized", **kw):
    pcfg = PopulationConfig(size=n, strategy=strategy, backend=backend,
                            hyper_space=SPACE, donate=False, **kw)
    return PopTrainer(ModuleAgent(td3, OBS, ACT), pcfg, seed=0)


# ---------------------------------------------------------------- unified API

def test_size_one_is_degenerate_null_strategy():
    tr = _trainer(n=1)
    assert isinstance(tr.strategy, NoEvolution)
    assert tr.hypers is None
    metrics, lineage = tr.step(_batch(KEY, 1))
    assert lineage is None
    assert np.isfinite(float(metrics["critic_loss"][0]))


@pytest.mark.parametrize("strategy", ["pbt", "cem", "none"])
def test_strategy_is_a_one_line_swap(strategy):
    tr = _trainer(strategy=strategy, pbt_interval=2)
    lineages = []
    for i in range(4):
        _, lineage = tr.step(_batch(jax.random.fold_in(KEY, i)),
                             fitness=np.arange(N, dtype=np.float32))
        if lineage is not None:
            lineages.append(np.asarray(lineage))
    if strategy == "none":
        assert lineages == []
    else:
        assert len(lineages) == 2
        if strategy == "cem":
            assert (lineages[0] == -1).all()  # members resampled, no parent


def test_backend_is_a_one_line_swap_and_matches():
    out = {}
    for backend in ("vectorized", "sequential"):
        tr = _trainer(backend=backend, pbt_interval=0)
        metrics, _ = tr.step(_batch(KEY))
        out[backend] = (tr.state, metrics)
    for a, b in zip(jax.tree.leaves(out["vectorized"][0].critic),
                    jax.tree.leaves(out["sequential"][0].critic)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_shared_critic_agent_backends_and_pbt_gather():
    batch = _batch(KEY)
    for backend in ("vectorized", "sequential"):
        pcfg = PopulationConfig(size=N, strategy="pbt", backend=backend,
                                pbt_interval=1, hyper_space=HyperSpace())
        tr = PopTrainer(SharedCriticAgent(OBS, ACT), pcfg, seed=0)
        _, lineage = tr.step(batch, fitness=np.arange(N, dtype=np.float32))
        # shared critic has no population axis: PBT must still work (member
        # components gathered, critic untouched)
        assert lineage is not None and lineage.shape == (N,)
        assert jax.tree.leaves(tr.actors)[0].shape[0] == N


def test_dvd_strategy_installs_coefficient_schedule():
    agent = SharedCriticAgent(OBS, ACT)
    pcfg = PopulationConfig(size=N, strategy="dvd", dvd_period=40)
    PopTrainer(agent, pcfg, seed=0)
    assert agent.dvd_coef_fn is not None


def test_fitness_window_is_capped():
    tr = _trainer(pbt_interval=0, fitness_window=3)
    for i in range(10):
        tr.step(_batch(jax.random.fold_in(KEY, i)),
                fitness=np.full((N,), float(i)))
    assert len(tr._window) == 3
    np.testing.assert_allclose(tr.fitness(), np.full((N,), 8.0))


def test_checkpoint_resume_roundtrip(tmp_path):
    pcfg = PopulationConfig(size=N, strategy="pbt", hyper_space=SPACE,
                            donate=False, pbt_interval=0)
    tr = PopTrainer(ModuleAgent(td3, OBS, ACT), pcfg, seed=0,
                    checkpoint_dir=tmp_path)
    for i in range(3):
        tr.step(_batch(jax.random.fold_in(KEY, i)))
    tr.save(blocking=True)

    tr2 = PopTrainer(ModuleAgent(td3, OBS, ACT), pcfg, seed=1,
                     checkpoint_dir=tmp_path)
    assert tr2.resume() == 2
    assert tr2.step_count == 3
    for a, b in zip(jax.tree.leaves(tr.state), jax.tree.leaves(tr2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(tr.hypers["actor_lr"]),
                                  np.asarray(tr2.hypers["actor_lr"]))


def test_lm_agent_fitness_is_negative_loss():
    metrics = {"loss": jnp.asarray([1.0, 2.0])}
    agent = LMAgent.__new__(LMAgent)  # fitness needs no model state
    np.testing.assert_allclose(np.asarray(agent.fitness_from_metrics(metrics)),
                               [-1.0, -2.0])


def test_unknown_names_raise():
    with pytest.raises(ValueError, match="strategy"):
        make_strategy(PopulationConfig(size=2, strategy="nope"))
    with pytest.raises(ValueError, match="backend"):
        make_update(ModuleAgent(td3, OBS, ACT), "nope")


# ------------------------------------------------------- chained-step metrics

def test_chain_steps_returns_windowed_mean_metrics():
    def update_fn(state, batch, hypers=None):
        return state + 1, {"loss": batch * 1.0, "step": state}

    chained = chain_steps(update_fn, 3)
    state, metrics = chained(jnp.asarray(0), jnp.asarray([1.0, 2.0, 3.0]))
    assert int(state) == 3
    # float metrics: mean over the chained window (k-sample fitness), not
    # the last step's value
    np.testing.assert_allclose(float(metrics["loss"]), 2.0)
    # integer metrics (counters) keep the final value
    assert int(metrics["step"]) == 2


# ----------------------------------------------- no n==1 branching anywhere

@pytest.mark.parametrize("rel", [
    "src/repro/launch/train.py",
    "examples/quickstart.py",
    "examples/pbt_td3.py",
    "examples/cemrl.py",
    "examples/dvd.py",
])
def test_consumers_have_no_population_size_branches(rel):
    src = open(os.path.join(REPO, rel)).read()
    assert not re.search(r"if\s+(n|population|pop|args\.population)\s*[=><!]=\s*1\b", src), \
        f"{rel} still branches on population size"
    assert not re.search(r"sys\.path\.insert", src), \
        f"{rel} still uses the sys.path hack"
