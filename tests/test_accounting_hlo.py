"""Parameter accounting vs published model sizes + HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.models.accounting import (active_param_count, model_flops,
                                     param_count)


# published (approximate) parameter counts; ours must land within 20%
# (we exclude modality frontends for musicgen/pixtral, and the assignment
# config for deepseek uses the bracketed 64-expert spec -> ~9B not 16B).
PUBLISHED = {
    "qwen2_0_5b": 0.49e9,
    "qwen2_1_5b": 1.54e9,
    "qwen3_8b": 8.2e9,
    "gemma_7b": 8.5e9,
    "qwen3_moe_30b_a3b": 30.5e9,
    "rwkv6_1_6b": 1.6e9,
    "zamba2_7b": 7.2e9,
    "pixtral_12b": 12.4e9,
    "musicgen_medium": 1.5e9,
}


@pytest.mark.parametrize("arch,target", sorted(PUBLISHED.items()))
def test_param_counts_match_published(arch, target):
    n = param_count(get_config(arch))
    assert 0.8 * target < n < 1.25 * target, f"{arch}: {n/1e9:.2f}B vs {target/1e9:.2f}B"


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3_moe_30b_a3b")
    total, active = param_count(cfg), active_param_count(cfg)
    # "A3B" = ~3B active of ~30B total
    assert active < 0.2 * total
    assert 2e9 < active < 5e9


def test_model_flops_train_vs_prefill():
    cfg = get_config("qwen3_8b")
    t = model_flops(cfg, LM_SHAPES["train_4k"])
    p = model_flops(cfg, LM_SHAPES["prefill_32k"])
    assert t / p == pytest.approx(3.0, rel=0.01)  # 6ND vs 2ND, same tokens


def test_hlo_analyzer_counts_scan_trip_counts():
    """cost_analysis counts a scan body once; our parser multiplies by the
    known_trip_count (the bug that motivated the custom analyzer)."""
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    a = analyze_hlo(compiled.as_text())
    expected = 7 * 2 * 64 * 128 * 128
    assert a["flops"] == pytest.approx(expected, rel=0.01)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
        ca = ca[0]
    assert ca["flops"] == pytest.approx(expected / 7, rel=0.01)  # the bug


def test_roofline_terms_bottleneck_selection():
    t = roofline_terms({"flops": 197e12, "traffic_bytes": 819e9 * 2,
                        "collective_bytes": 50e9 * 0.5})
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["t_memory"] == pytest.approx(2.0)
    assert t["bottleneck"] == "memory"
    assert t["roofline_s"] == pytest.approx(2.0)
