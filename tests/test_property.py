"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional in the CI image; skip the whole module without it
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import HyperSpace, PopulationConfig
from repro.core import pbt_step, sample_hypers
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.optim.compress import int8_compress, int8_decompress
from repro.nn.rwkv6 import wkv6_chunked, wkv6_scan

SPACE = HyperSpace(log_uniform=(("lr", 1e-5, 1e-2),),
                   uniform=(("discount", 0.9, 1.0),))


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 16), st.integers(0, 1000),
       st.floats(0.1, 0.49))
def test_pbt_invariants(n, seed, frac):
    """Population size preserved; survivors keep their own state; replaced
    members' parents come from the top-k; hypers stay in bounds."""
    key = jax.random.PRNGKey(seed)
    pop = {"w": jax.random.normal(key, (n, 3))}
    hypers = sample_hypers(key, SPACE, n)
    fitness = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    pcfg = PopulationConfig(size=n, exploit_frac=frac, hyper_space=SPACE)
    new_pop, new_h, parents = pbt_step(key, pop, hypers, fitness, pcfg)
    parents = np.asarray(parents)
    k = max(1, int(round(n * frac)))
    order = np.argsort(np.asarray(fitness))
    bottom, top = set(order[:k]), set(order[-k:])
    assert new_pop["w"].shape == (n, 3)
    for i in range(n):
        if i in bottom:
            assert parents[i] in top
        else:
            assert parents[i] == i
        np.testing.assert_allclose(np.asarray(new_pop["w"][i]),
                                   np.asarray(pop["w"][parents[i]]))
    for name, lo, hi in SPACE.log_uniform + SPACE.uniform:
        vals = np.asarray(new_h[name])
        assert (vals >= lo - 1e-9).all() and (vals <= hi + 1e-9).all()


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 8), st.integers(1, 40), st.integers(0, 100))
def test_replay_buffer_fifo_matches_numpy_oracle(cap_mul, n_items, seed):
    capacity = 8 * cap_mul
    rng = np.random.default_rng(seed)
    items = rng.normal(size=(n_items, 3)).astype(np.float32)
    buf = buffer_init(capacity, {"x": jnp.zeros((3,), jnp.float32)})
    oracle = np.zeros((capacity, 3), np.float32)
    pos = 0
    for i in range(0, n_items, 4):
        chunk = items[i:i + 4]
        buf = buffer_add(buf, {"x": jnp.asarray(chunk)})
        for row in chunk:
            oracle[pos % capacity] = row
            pos += 1
    np.testing.assert_allclose(np.asarray(buf.data["x"]), oracle)
    assert int(buf.insert_pos) == pos % capacity
    assert int(buf.total) == n_items - n_items % 1
    # samples only come from valid region
    if n_items >= 4:
        s = buffer_sample(buf, jax.random.PRNGKey(seed), 16)
        valid = oracle[:min(pos, capacity)]
        for row in np.asarray(s["x"]):
            assert any(np.allclose(row, v) for v in valid)


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 1000), st.floats(1e-3, 1e3))
def test_int8_compress_error_bound(seed, scale):
    g = scale * jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, s = int8_compress(g)
    err = jnp.max(jnp.abs(int8_decompress(q, s) - g))
    amax = float(jnp.max(jnp.abs(g)))
    assert float(err) <= amax / 127.0 + 1e-6


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 50), st.sampled_from([16, 32]), st.sampled_from([1, 2]))
def test_wkv6_chunked_equals_scan_property(seed, chunk, h):
    b, s, d = 1, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, d)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, d)) - 2.0)
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    st0 = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    y1, s1 = wkv6_scan(r, k, v, lw, u, st0)
    y2, s2 = wkv6_chunked(r, k, v, lw, u, st0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 200))
def test_hyper_sampling_within_prior(seed):
    h = sample_hypers(jax.random.PRNGKey(seed), SPACE, 16)
    assert (np.asarray(h["lr"]) >= 1e-5).all()
    assert (np.asarray(h["lr"]) <= 1e-2).all()
    assert (np.asarray(h["discount"]) >= 0.9).all()
    assert (np.asarray(h["discount"]) <= 1.0).all()
