"""repro.serve acceptance: the serving forward is bit-exact with the
training-time Evaluator's on all four RL algorithms, the batched ensemble
call moves no bytes between host and device (transfer_guard), serving-set
selection obeys its fitness+diversity contract, ContinuousEvaluator
promotes/demotes from live checkpoints without a trainer restore, the
strict ``peek_extra`` raises on pre-metadata checkpoints, and the three
ensemble reductions compute what they claim."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import PopulationConfig
from repro.envs import make
from repro.pop import PopTrainer
from repro.rl import make_agent
from repro.rollout import Evaluator
from repro.rollout.collector import default_exploration
from repro.serve import (BatchServer, ContinuousEvaluator, PolicyForward,
                         load_actor_stack, make_serving_set,
                         probe_observations, select_members)

KEY = jax.random.PRNGKey(0)

ALGO_ENVS = [("td3", "pendulum"), ("sac", "pendulum"),
             ("dqn", "cartpole"), ("ppo", "pendulum")]


def _population(algo, env, n=3, key=KEY):
    agent = make_agent(algo, env.spec)
    return agent, agent.actor_params(agent.population_init(key, n))


def _td3_server(n=4, max_batch=8, mode="mean", mesh=None, key=KEY):
    env = make("pendulum")
    agent, actors = _population("td3", env, n, key)
    sset = make_serving_set(actors, np.arange(n), step=0,
                            fitness=np.linspace(0.0, 1.0, n))
    server = BatchServer(PolicyForward.for_agent(agent), env.spec, sset,
                         max_batch=max_batch, mode=mode, mesh=mesh)
    return env, agent, actors, server


# ------------------------------------------------- forward == evaluator
@pytest.mark.parametrize("algo,env_name", ALGO_ENVS)
def test_policy_forward_matches_evaluator(algo, env_name):
    """The serving engine's PolicyForward and the Evaluator the training
    loop scores fitness with produce bit-identical deterministic actions
    on the same observations (greedy/mean heads; DQN's greedy head ignores
    epsilon, i.e. epsilon=0).  Promotion fitness therefore describes
    exactly the policy that serves."""
    env = make(env_name)
    agent, actors = _population(algo, env)
    # the evaluator exactly as RolloutEngine builds it during training
    ev = Evaluator(env, default_exploration(agent), num_envs=2, num_steps=4)
    serving = PolicyForward.for_agent(agent)

    # on-trajectory observations (resets) + off-trajectory random ones
    obs = np.concatenate([
        np.asarray(probe_observations(env, KEY, 8)),
        np.asarray(jax.random.normal(KEY, (8, env.spec.obs_dim)))])
    evaluator_actions = jax.jit(jax.vmap(ev.forward.member,
                                         in_axes=(0, None)))(actors, obs)
    serving_actions = jax.jit(serving.members)(actors, obs)
    np.testing.assert_array_equal(np.asarray(serving_actions),
                                  np.asarray(evaluator_actions))
    if env.spec.discrete:
        assert np.asarray(serving_actions).dtype.kind in "iu"


def test_evaluator_forward_composition():
    """Evaluator accepts a prebuilt PolicyForward and exposes it; passing
    both or neither of policy_fn/forward is an error."""
    env = make("pendulum")
    agent, actors = _population("td3", env)
    fwd = PolicyForward.for_agent(agent)
    ev = Evaluator(env, forward=fwd, num_envs=2, num_steps=4)
    assert ev.forward is fwd and ev.policy_fn is fwd.policy_fn
    fit = ev.evaluate(actors, KEY)
    assert np.asarray(fit).shape == (3,)
    with pytest.raises(ValueError):
        Evaluator(env, default_exploration(agent), forward=fwd)
    with pytest.raises(ValueError):
        Evaluator(env)


# ------------------------------------------------------- transfer guard
def test_ensemble_call_no_host_round_trip():
    """One jitted donated call serves the whole ensemble: a warm call on a
    device-resident padded batch runs under transfer_guard('disallow') —
    no implicit host<->device traffic anywhere in the hot path."""
    env, _, _, server = _td3_server()
    server.warmup()
    obs = server.place_request(np.ones((8, env.spec.obs_dim), np.float32))
    with jax.transfer_guard("disallow"):
        acts = server.infer_device(obs)
        jax.block_until_ready(acts)
    assert np.asarray(acts).shape == (8, env.spec.act_dim)


def test_ensemble_call_no_host_round_trip_with_telemetry(tmp_path):
    """The PR's hard constraint, serving side: with serving telemetry
    live (latency window + JSONL sink), the warm ensemble call is STILL
    one jitted donated call with no implicit transfers — all telemetry
    bookkeeping is host-side around the call, and the device->host fetch
    of row values happens on the sink's (unguarded) worker thread."""
    from repro.telemetry import JSONLSink, RunTelemetry

    env = make("pendulum")
    agent, actors = _population("td3", env, 3)
    sset = make_serving_set(actors, np.arange(3), step=0,
                            fitness=np.linspace(0.0, 1.0, 3))
    tel = RunTelemetry(JSONLSink(tmp_path / "telemetry.jsonl", strict=True))
    server = BatchServer(PolicyForward.for_agent(agent), env.spec, sset,
                         max_batch=8, telemetry=tel, telemetry_every=2)
    server.warmup()
    obs = server.place_request(np.ones((8, env.spec.obs_dim), np.float32))
    with jax.transfer_guard("disallow"):
        acts = server.infer_device(obs)
        jax.block_until_ready(acts)
    # the full serve() path (padding + explicit ingress/egress) feeds the
    # latency window; 2 batches hit telemetry_every and emit a serve row
    for _ in range(2):
        server.serve(np.ones((5, env.spec.obs_dim), np.float32))
    server.report_telemetry()   # tail flush is idempotent on empty window
    tel.close()

    import json
    rows = [json.loads(line) for line in
            (tmp_path / "telemetry.jsonl").read_text().splitlines()]
    serve_rows = [r for r in rows if r["kind"] == "serve"]
    assert len(serve_rows) == 1          # window reset after the report
    (srow,) = serve_rows
    assert srow["count"] == 2 and srow["requests"] == 10
    assert srow["p99_ms"] >= srow["p50_ms"] > 0
    assert srow["fill"] == pytest.approx(5 / 8)
    assert srow["ensemble"] == 3 and srow["mode"] == "mean"


def test_warmup_not_counted_as_latency_sample():
    _, _, _, server = _td3_server(n=2, max_batch=4)
    server.warmup()
    assert server._window.count == 0     # a compile is not a sample
    server.serve(np.zeros((2, server.spec.obs_dim), np.float32))
    assert server._window.count == 1


# ------------------------------------------------------ member selection
def test_select_members_fittest_always_first():
    fitness = np.array([0.0, 5.0, 1.0, 2.0])
    emb = np.eye(4)
    picked = select_members(fitness, emb, 2)
    assert picked[0] == 1
    picked = select_members(fitness, None, 3)
    assert picked.tolist() == [1, 3, 2]   # pure fitness ranking


def test_select_members_prefers_diverse_over_clone():
    """Equal-ish fitness: the second slot goes to the behaviorally distant
    member, not the near-clone of the fittest."""
    fitness = np.array([1.0, 0.99, 0.5])
    emb = np.array([[0.0, 0.0], [0.01, 0.0], [3.0, 3.0]])
    picked = select_members(fitness, emb, 2, diversity_weight=5.0)
    assert picked.tolist() == [0, 2]
    # diversity off: fitness alone picks the clone
    picked = select_members(fitness, emb, 2, diversity_weight=0.0)
    assert picked.tolist() == [0, 1]


def test_select_members_edges():
    fitness = np.array([1.0, 2.0])
    assert select_members(fitness, None, 10).tolist() == [1, 0]  # k clamped
    assert len(select_members(None, np.eye(3), 2)) == 2  # diversity alone
    with pytest.raises(ValueError):
        select_members(None, None, 2)


def test_make_serving_set_gathers_and_ranks():
    env = make("pendulum")
    _, actors = _population("td3", env, n=4)
    sset = make_serving_set(actors, [2, 0], step=7,
                            fitness=np.array([1.0, 9.0, 3.0, 0.0]))
    assert sset.size == 2 and sset.step == 7
    assert sset.fitness.tolist() == [3.0, 1.0]
    assert sset.best == 0    # slot 0 (population member 2) is fittest
    lead = jax.tree.leaves(sset.params)[0]
    ref = jax.tree.leaves(actors)[0]
    np.testing.assert_array_equal(np.asarray(lead),
                                  np.asarray(ref[np.array([2, 0])]))
    assert "step=7" in sset.describe()


# ------------------------------------------------------------ reductions
def test_mean_reduction_matches_member_average():
    env, agent, actors, server = _td3_server(n=4, max_batch=6)
    obs = np.asarray(jax.random.normal(KEY, (6, env.spec.obs_dim)),
                     np.float32)
    got = server.serve(obs)
    per_member = jax.jit(server.forward.members)(actors, jnp.asarray(obs))
    np.testing.assert_allclose(got, np.asarray(per_member).mean(0),
                               rtol=1e-6, atol=1e-6)


def test_best_reduction_serves_the_fittest_member():
    env, agent, actors, server = _td3_server(n=4, max_batch=6, mode="best")
    assert server.set.best == 3          # fitness = linspace -> last wins
    obs = np.asarray(jax.random.normal(KEY, (6, env.spec.obs_dim)),
                     np.float32)
    got = server.serve(obs)
    per_member = jax.jit(server.forward.members)(actors, jnp.asarray(obs))
    np.testing.assert_allclose(got, np.asarray(per_member)[3],
                               rtol=1e-6, atol=1e-6)


def test_vote_reduction_is_member_plurality():
    env = make("cartpole")
    agent, actors = _population("dqn", env, n=5)
    sset = make_serving_set(actors, np.arange(5), step=0)
    server = BatchServer(PolicyForward.for_agent(agent), env.spec, sset,
                         max_batch=4, mode="vote")
    obs = np.asarray(jax.random.normal(KEY, (4, env.spec.obs_dim)),
                     np.float32)
    got = server.serve(obs)
    votes = np.asarray(jax.jit(server.forward.members)(
        actors, jnp.asarray(obs)))                       # (5, 4) greedy acts
    expect = [np.bincount(votes[:, b], minlength=env.spec.act_dim).argmax()
              for b in range(4)]
    np.testing.assert_array_equal(got, expect)


def test_vote_needs_discrete_actions():
    env = make("pendulum")
    agent, _ = _population("td3", env)
    with pytest.raises(ValueError, match="discrete"):
        BatchServer(PolicyForward.for_agent(agent), env.spec, mode="vote")
    with pytest.raises(ValueError, match="unknown reduction"):
        BatchServer(PolicyForward.for_agent(agent), env.spec, mode="median")


def test_serve_padding_and_tiling_invariant():
    """Answers are independent of how requests pack into the fixed batch:
    a short batch (padded), an exact batch, and an overlong batch (tiled)
    agree element-wise; a single request round-trips without a batch dim."""
    env, _, _, server = _td3_server(n=2, max_batch=4)
    obs = np.asarray(jax.random.normal(KEY, (10, env.spec.obs_dim)),
                     np.float32)
    full = server.serve(obs)                       # 4 + 4 + 2(padded)
    assert full.shape == (10, env.spec.act_dim)
    np.testing.assert_allclose(server.serve(obs[:3]), full[:3],
                               rtol=1e-6, atol=1e-6)
    one = server.serve(obs[0])
    assert one.shape == (env.spec.act_dim,)
    np.testing.assert_allclose(one, full[0], rtol=1e-6, atol=1e-6)
    assert server.requests_served == 10 + 3 + 1


def test_submit_flush_queue():
    env, _, _, server = _td3_server(n=2, max_batch=3)
    obs = np.asarray(jax.random.normal(KEY, (3, env.spec.obs_dim)),
                     np.float32)
    slots = [server.submit(o) for o in obs]
    assert slots == [0, 1, 2]
    with pytest.raises(ValueError, match="queue full"):
        server.submit(obs[0])
    np.testing.assert_allclose(server.flush(), server.serve(obs),
                               rtol=1e-6, atol=1e-6)
    assert server.flush().shape == (0,)            # empty queue

    unset = BatchServer(server.forward, env.spec, max_batch=3)
    with pytest.raises(ValueError, match="no ServingSet"):
        unset.serve(obs)


# ------------------------------------------- continuous promotion
def _tiny_trainer(tmp_path, env, n=4):
    agent = make_agent("td3", env.spec)
    pcfg = PopulationConfig(size=n, strategy="none", donate=False)
    return agent, PopTrainer(agent, pcfg, seed=0,
                             checkpoint_dir=str(tmp_path))


def test_continuous_evaluator_promotes_and_demotes(tmp_path):
    env = make("pendulum")
    agent, trainer = _tiny_trainer(tmp_path, env)
    trainer.step_count = 1
    trainer.report_fitness(np.array([9.0, 8.0, 0.0, 1.0]))
    trainer.save(blocking=True)

    watcher = ContinuousEvaluator(trainer._mgr, agent, size=2,
                                  diversity_weight=0.0)   # fitness-only
    sset = watcher.poll()
    assert sset is not None and sset.step == 0
    assert sorted(sset.members.tolist()) == [0, 1]
    assert watcher.poll() is None                  # unchanged checkpoint

    # training continues: fitness order flips, a newer checkpoint lands
    # (values dominate the first report — trainer.fitness() is the mean of
    # the live window, not just the latest entry)
    trainer.step_count = 11
    trainer.report_fitness(np.array([0.0, 1.0, 99.0, 88.0]))
    trainer.save(blocking=True)
    server_env, _, _, server = _td3_server(n=2, max_batch=4)
    newer = watcher.poll(server)
    assert newer is not None and newer.step == 10
    assert sorted(newer.members.tolist()) == [2, 3]
    ev = watcher.events[-1]
    assert sorted(ev["promoted"]) == [2, 3]
    assert sorted(ev["demoted"]) == [0, 1]
    assert server.set is newer                     # installed into server
    server.serve(np.zeros((4, env.spec.obs_dim), np.float32))


def test_promotion_audit_trail_persists_through_sink(tmp_path):
    """Every promote/demote event lands in the JSONL record (not just the
    in-memory ``events`` list), so a served ensemble's provenance survives
    a process restart."""
    import json

    from repro.telemetry import JSONLSink, RunTelemetry

    env = make("pendulum")
    agent, trainer = _tiny_trainer(tmp_path / "ckpt", env)
    tel = RunTelemetry(JSONLSink(tmp_path / "telemetry.jsonl", strict=True))
    trainer.step_count = 1
    trainer.report_fitness(np.array([9.0, 8.0, 0.0, 1.0]))
    trainer.save(blocking=True)
    watcher = ContinuousEvaluator(trainer._mgr, agent, size=2,
                                  diversity_weight=0.0, telemetry=tel)
    watcher.poll()
    trainer.step_count = 11
    trainer.report_fitness(np.array([0.0, 1.0, 99.0, 88.0]))
    trainer.save(blocking=True)
    watcher.poll()
    tel.close()

    rows = [json.loads(line) for line in
            (tmp_path / "telemetry.jsonl").read_text().splitlines()]
    promos = [r for r in rows if r["kind"] == "promotion"]
    assert len(promos) == len(watcher.events) == 2
    for row, event in zip(promos, watcher.events):
        for key in ("step", "promoted", "demoted", "members"):
            assert row[key] == event[key]
    assert promos[1]["population"] == 4
    assert len(promos[1]["fitness"]) == 4


def test_promoted_params_match_checkpointed_actors(tmp_path):
    """load_actor_stack restores the exact actor arrays the trainer saved —
    no trainer restore, bit-identical params, so a promoted member's
    serving actions ARE its training-time evaluation actions."""
    env = make("pendulum")
    agent, trainer = _tiny_trainer(tmp_path, env)
    trainer.step_count = 1
    trainer.report_fitness(np.array([1.0, 2.0, 3.0, 0.0]))
    trainer.save(blocking=True)

    actors, extra = load_actor_stack(trainer._mgr, agent)
    assert extra["size"] == 4 and extra["fitness"][2] == 3.0
    for got, ref in zip(jax.tree.leaves(actors),
                        jax.tree.leaves(trainer.actors)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    fwd = PolicyForward.for_agent(agent)
    obs = np.asarray(probe_observations(env, KEY, 8))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fwd.members)(actors, obs)),
        np.asarray(jax.jit(fwd.members)(trainer.actors, obs)))


def test_promotion_without_fitness_uses_probes(tmp_path):
    """A checkpoint saved right after an evolve carries fitness=None; with
    probe observations the watcher still promotes (diversity alone), and
    with neither it falls back to by-index promotion, loudly."""
    env = make("pendulum")
    agent, trainer = _tiny_trainer(tmp_path, env)
    trainer.step_count = 1
    trainer.save(blocking=True)                    # empty fitness window
    assert trainer._mgr.peek_extra()["fitness"] is None

    probes = probe_observations(env, KEY, 8)
    sset = ContinuousEvaluator(trainer._mgr, agent, size=2,
                               probe_obs=probes).poll()
    assert sset.size == 2 and sset.fitness is None

    blind = ContinuousEvaluator(trainer._mgr, agent, size=2)
    with pytest.warns(UserWarning, match="promoting by member index"):
        sset = blind.poll()
    assert sset.members.tolist() == [0, 1]


# ---------------------------------------------------- strict peek_extra
def test_peek_extra_strict_on_legacy_checkpoints(tmp_path):
    """A checkpoint lacking the size/fitness extras (pre-PR-3 producer)
    raises a clear KeyError instead of returning a partial dict;
    require=() is the raw-read escape hatch.  An empty dir stays None."""
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.peek_extra() is None
    mgr.save(3, {"w": np.zeros(2)}, extra={"loss": 1.5})
    with pytest.raises(KeyError, match="lacks extras.*size"):
        mgr.peek_extra()
    raw = mgr.peek_extra(require=())
    assert raw["loss"] == 1.5 and raw["step"] == 3


def test_load_actor_stack_rejects_unservable_checkpoint(tmp_path):
    """A checkpoint with extras but no 'actors' aux tree (a producer that
    never recorded serving params) is rejected with guidance, and an empty
    dir raises FileNotFoundError."""
    env = make("pendulum")
    agent = make_agent("td3", env.spec)
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        load_actor_stack(mgr, agent)
    mgr.save(0, {"w": np.zeros(2)},
             extra={"size": 2, "fitness": None})
    with pytest.raises(ValueError, match="no 'actors' aux"):
        load_actor_stack(mgr, agent)


# ------------------------------------------------------------- islands
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="islands serving needs >1 device")
def test_islands_mesh_matches_single_device():
    from repro.elastic import plan_layout
    n = 4
    mesh = plan_layout(len(jax.devices()), n).mesh
    env, agent, actors, plain = _td3_server(n=n, max_batch=4)
    _, _, _, sharded = _td3_server(n=n, max_batch=4, mesh=mesh)
    obs = np.asarray(jax.random.normal(KEY, (4, env.spec.obs_dim)),
                     np.float32)
    np.testing.assert_allclose(sharded.serve(obs), plain.serve(obs),
                               rtol=1e-5, atol=1e-5)
    # the sharded call is still one program with no implicit transfers:
    # place_request replicates the batch over the mesh explicitly
    ready = sharded.place_request(obs)
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(sharded.infer_device(ready))
    # an ensemble the mesh cannot tile is rejected at install time
    islands = mesh.shape["pop"]
    if islands > 1:
        bad = make_serving_set(actors, np.arange(islands + 1))
        with pytest.raises(ValueError, match="does not split"):
            sharded.install(bad)


def test_warmup_silences_donation_note(recwarn):
    _, _, _, server = _td3_server(n=2, max_batch=4)
    server.warmup()
    assert not [w for w in recwarn.list
                if "donated buffers" in str(w.message)]
