"""Data-parallel update with int8 error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro import compat

from repro.optim import adam
from repro.optim.dp import make_dp_update


def _mesh():
    return compat.make_mesh((len(jax.devices()),), ("data",))


def _problem():
    target = jnp.arange(8.0) / 4 - 1.0

    def grad_fn(params, batch):
        def loss(p):
            pred = batch @ p["w"]
            return jnp.mean((pred - batch @ target) ** 2)
        return jax.value_and_grad(loss)(params)

    return target, grad_fn


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_dp_update_converges(compression):
    mesh = _mesh()
    target, grad_fn = _problem()
    params = {"w": jnp.zeros(8)}
    opt_init, opt_update = adam(lr=0.05)
    opt_state = opt_init(params)
    error = jax.tree.map(jnp.zeros_like, params)
    update = make_dp_update(grad_fn, opt_update, mesh,
                            compression=compression)
    key = jax.random.PRNGKey(0)
    with compat.set_mesh(mesh):
        for i in range(300):
            batch = jax.random.normal(jax.random.fold_in(key, i),
                                      (8 * len(jax.devices()), 8))
            params, opt_state, error, loss = update(params, opt_state, error,
                                                    batch)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_compressed_matches_plain_within_tolerance():
    mesh = _mesh()
    target, grad_fn = _problem()
    opt_init, opt_update = adam(lr=0.05)
    outs = {}
    for compression in ("none", "int8"):
        params = {"w": jnp.zeros(8)}
        opt_state = opt_init(params)
        error = jax.tree.map(jnp.zeros_like, params)
        update = make_dp_update(grad_fn, opt_update, mesh,
                                compression=compression)
        key = jax.random.PRNGKey(1)
        with compat.set_mesh(mesh):
            for i in range(100):
                batch = jax.random.normal(jax.random.fold_in(key, i),
                                          (8 * len(jax.devices()), 8))
                params, opt_state, error, loss = update(
                    params, opt_state, error, batch)
        outs[compression] = np.asarray(params["w"])
    np.testing.assert_allclose(outs["int8"], outs["none"], atol=0.1)
