"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness; plus a decode
step exercising the KV-cache/SSM-state path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_SHAPES, TrainConfig, applicable_shapes, get_config
from repro.configs.registry import _ARCHS
from repro.models import lm as L

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model))
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (b, cfg.num_frontend_positions, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", _ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    params = L.init_params(KEY, cfg)
    batch = _smoke_batch(cfg)
    logits, _, aux = L.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    opt_init, train_step = L.make_train_step(
        cfg, TrainConfig(total_steps=10, warmup_steps=0))
    opt = opt_init(params)
    p2, opt2, metrics = jax.jit(train_step)(params, opt, batch,
                                            jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", _ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    params = L.init_params(KEY, cfg)
    serve = jax.jit(L.make_serve_step(cfg))
    state = L.init_decode_state(cfg, 2, 16)
    batch = {"tokens": jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.random.normal(KEY, (2, 1, cfg.d_model))
    logits, state = serve(params, batch, state, jnp.zeros((), jnp.int32))
    logits, state = serve(params, batch, state, jnp.ones((), jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_applicable_shapes_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md)."""
    long_ok = {a for a in _ARCHS
               if "long_500k" in applicable_shapes(get_config(a))}
    assert long_ok == {"rwkv6_1_6b", "zamba2_7b", "rwkv6_test"}
    for a in _ARCHS:
        shapes = applicable_shapes(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the 10-arch table)."""
    spec = {
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "rwkv6_1_6b": (24, 2048, 32, 32, 7168, 65536),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen3_8b": (36, 4096, 32, 8, 12288, 151936),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
    }
    for arch, (nl, dm, nh, kv, ff, vs) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, dm, nh, kv, ff, vs), arch
    assert get_config("qwen3_moe_30b_a3b").moe.num_experts == 128
    assert get_config("qwen3_moe_30b_a3b").moe.top_k == 8
    assert get_config("deepseek_v2_lite_16b").moe.top_k == 6
    assert get_config("deepseek_v2_lite_16b").mla.kv_lora_rank == 512
    assert get_config("zamba2_7b").ssm_state == 64
    assert get_config("gemma_7b").hd == 256


def test_logits_chunk_loss_equivalence():
    cfg = get_config("qwen2_0_5b").smoke()
    params = L.init_params(KEY, cfg)
    batch = _smoke_batch(cfg)
    l1, _ = L.lm_loss(params, cfg, batch)
    l2, _ = L.lm_loss(params, cfg.replace(logits_chunk=8), batch)
    assert abs(float(l1) - float(l2)) < 1e-5
