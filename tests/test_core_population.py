"""Tests for the paper's core contribution (vectorize/PBT/CEM/DvD/shared)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HyperSpace, PopulationConfig
from repro.core import (cem_init, cem_sample, cem_update, dvd_loss,
                        make_shared_critic_update, pbt_step, population_init,
                        sample_hypers, sequential_update, vectorized_update)
from repro.core.dvd import behavior_embedding
from repro.core.population import member, population_size
from repro.core.shared import init as shared_init, \
    sequential_shared_critic_update
from repro.rl import dqn, sac, td3

KEY = jax.random.PRNGKey(0)
N, B, OBS, ACT = 4, 16, 3, 2

SPACE = HyperSpace(
    log_uniform=(("actor_lr", 3e-5, 3e-3), ("critic_lr", 3e-5, 3e-3)),
    uniform=(("policy_freq", 0.2, 1.0), ("noise", 0.0, 1.0),
             ("discount", 0.9, 1.0)))


def _batch(key, n=N):
    ks = jax.random.split(key, 5)
    return {
        "obs": jax.random.normal(ks[0], (n, B, OBS)),
        "action": jax.random.uniform(ks[1], (n, B, ACT), minval=-1, maxval=1),
        "reward": jax.random.normal(ks[2], (n, B)),
        "next_obs": jax.random.normal(ks[3], (n, B, OBS)),
        "done": jnp.zeros((n, B)),
    }


def _max_err(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_vectorized_equals_sequential_td3():
    """The paper's central claim: vmapped population update == per-member
    sequential updates (exactly, not just statistically)."""
    pop = population_init(lambda k: td3.init(k, OBS, ACT), KEY, N)
    hypers = sample_hypers(KEY, SPACE, N)
    batch = _batch(KEY)
    s_vec, m_vec = vectorized_update(td3.update, donate=False)(pop, batch, hypers)
    s_seq, m_seq = sequential_update(td3.update)(pop, batch, hypers)
    # fp tolerance: vmapped batched matmuls reassociate reductions
    assert _max_err(s_vec.actor, s_seq.actor) < 5e-5
    assert _max_err(s_vec.critic, s_seq.critic) < 5e-5


def test_vectorized_equals_sequential_sac_dqn():
    pop = population_init(lambda k: sac.init(k, OBS, ACT), KEY, N)
    batch = _batch(KEY)
    sv, _ = vectorized_update(sac.update, donate=False)(pop, batch, None)
    ss, _ = sequential_update(sac.update)(pop, batch, None)
    assert _max_err(sv.actor, ss.actor) < 5e-5

    popd = population_init(lambda k: dqn.init(k, OBS, 3), KEY, N)
    db = dict(_batch(KEY), action=jax.random.randint(KEY, (N, B), 0, 3))
    dv, _ = vectorized_update(dqn.update, donate=False)(popd, db, None)
    ds, _ = sequential_update(dqn.update)(popd, db, None)
    assert _max_err(dv.q, ds.q) < 5e-5


def test_chained_steps_equal_repeated_single_steps():
    pop = population_init(lambda k: td3.init(k, OBS, ACT), KEY, N)
    steps = 3
    batches = jax.tree.map(
        lambda x: jnp.stack([x] * steps), _batch(KEY))
    chained, _ = vectorized_update(td3.update, num_steps=steps,
                                   donate=False)(pop, batches, None)
    one = vectorized_update(td3.update, donate=False)
    state = pop
    for _ in range(steps):
        state, _ = one(state, jax.tree.map(lambda x: x[0], batches), None)
    assert _max_err(chained.critic, state.critic) < 5e-5


def test_pbt_exploit_copies_top_and_preserves_size():
    pop = population_init(lambda k: td3.init(k, OBS, ACT), KEY, N)
    hypers = sample_hypers(KEY, SPACE, N)
    fitness = jnp.asarray([0.0, 10.0, 5.0, 7.0])
    pcfg = PopulationConfig(size=N, exploit_frac=0.25, hyper_space=SPACE)
    new_pop, new_h, parents = pbt_step(KEY, pop, hypers, fitness, pcfg)
    parents = np.asarray(parents)
    assert population_size(new_pop) == N
    # worst member (0) replaced by a member of the top-25% (member 1)
    assert parents[0] == 1
    assert list(parents[1:]) == [1, 2, 3]
    got = jax.tree.leaves(member(new_pop, 0).actor)[0]
    want = jax.tree.leaves(member(pop, 1).actor)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_perturb_hypers_clips_to_prior_bounds():
    from repro.core import perturb_hypers
    hypers = sample_hypers(KEY, SPACE, N)
    # push every member to the edge of the prior so scale^{+1} would escape
    edged = {k: jnp.full_like(v, dict(
        (n, hi) for n, _, hi in SPACE.log_uniform + SPACE.uniform)[k])
        for k, v in hypers.items()}
    mask = jnp.ones((N,), bool)
    for seed in range(5):
        out = perturb_hypers(jax.random.PRNGKey(seed), edged, SPACE, mask)
        for name, lo, hi in SPACE.log_uniform + SPACE.uniform:
            vals = np.asarray(out[name])
            assert (vals >= lo - 1e-9).all() and (vals <= hi + 1e-9).all()


def test_perturb_hypers_untouched_members_are_bit_identical():
    from repro.core import perturb_hypers
    hypers = sample_hypers(KEY, SPACE, N)
    mask = jnp.asarray([True, False, True, False])
    out = perturb_hypers(KEY, hypers, SPACE, mask)
    for name in hypers:
        np.testing.assert_array_equal(np.asarray(out[name])[~np.asarray(mask)],
                                      np.asarray(hypers[name])[~np.asarray(mask)])


def test_pbt_lineage_survivors_keep_identity_parents_from_topk():
    pop = population_init(lambda k: td3.init(k, OBS, ACT), KEY, 8)
    hypers = sample_hypers(KEY, SPACE, 8)
    fitness = jnp.arange(8, dtype=jnp.float32)   # member 7 best
    pcfg = PopulationConfig(size=8, exploit_frac=0.25, hyper_space=SPACE)
    for seed in range(5):
        _, _, parents = pbt_step(jax.random.PRNGKey(seed), pop, hypers,
                                 fitness, pcfg)
        parents = np.asarray(parents)
        k = 2  # bottom/top 25% of 8
        # survivors hold their own state
        np.testing.assert_array_equal(parents[k:], np.arange(k, 8))
        # replaced members draw parents from the top-k only
        assert set(parents[:k]) <= {6, 7}


def test_pbt_explored_hypers_stay_in_bounds():
    pop = population_init(lambda k: td3.init(k, OBS, ACT), KEY, N)
    hypers = sample_hypers(KEY, SPACE, N)
    pcfg = PopulationConfig(size=N, exploit_frac=0.5, hyper_space=SPACE)
    for seed in range(5):
        _, new_h, _ = pbt_step(jax.random.PRNGKey(seed), pop, hypers,
                               jnp.arange(N, dtype=jnp.float32), pcfg)
        for name, lo, hi in SPACE.log_uniform + SPACE.uniform:
            vals = np.asarray(new_h[name])
            assert (vals >= lo - 1e-9).all() and (vals <= hi + 1e-9).all()


def test_cem_contracts_on_quadratic():
    template = {"w": jnp.zeros((8,))}
    state, unravel = cem_init(template, sigma_init=1.0)
    target = jnp.arange(8.0) / 8
    key = KEY
    for i in range(30):
        key, ks = jax.random.split(key)
        samples = cem_sample(ks, state, 32)
        fitness = -jnp.sum((samples - target) ** 2, axis=-1)
        state = cem_update(state, samples, fitness)
    assert float(jnp.max(jnp.abs(state.mean - target))) < 0.15
    assert float(jnp.mean(state.var)) < 0.5


def test_dvd_loss_prefers_diverse_populations():
    emb_same = jnp.ones((4, 16))
    emb_diverse = jax.random.normal(KEY, (4, 16))
    assert float(dvd_loss(emb_diverse)) < float(dvd_loss(emb_same))


def test_shared_critic_vectorized_update_runs_and_matches_avg_loss():
    st = shared_init(KEY, OBS, ACT, N)
    batch = _batch(KEY)
    upd = jax.jit(make_shared_critic_update())
    st2, m = upd(st, batch, None)
    assert np.isfinite(float(m["critic_loss"]))
    # critic received ONE update (paper §4.2: loss averaged over members)
    assert int(st2.step) == 1
    # sequential arm also runs (baseline for Fig. 4)
    st3, m3 = jax.jit(sequential_shared_critic_update())(st, batch, None)
    assert np.isfinite(float(m3["critic_loss"]))


def test_behavior_embedding_shape():
    from repro.rl import networks as nets
    pols = jax.vmap(lambda k: nets.actor_init(k, OBS, ACT))(
        jax.random.split(KEY, N))
    probe = jax.random.normal(KEY, (7, OBS))
    emb = behavior_embedding(nets.actor_apply, pols, probe)
    assert emb.shape == (N, 7 * ACT)
