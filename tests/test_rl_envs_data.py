"""RL agents, pure-JAX envs, replay buffer, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (DoubleBuffer, Prefetcher, buffer_add, buffer_init,
                        buffer_sample, host_batches)
from repro.envs import make, rollout
from repro.rl import dqn, sac, td3

KEY = jax.random.PRNGKey(0)


def test_envs_step_shapes_and_reset():
    for name in ("pendulum", "reacher", "cartpole"):
        env = make(name)
        state, obs = env.reset(KEY)
        assert obs.shape == (env.spec.obs_dim,)
        if env.spec.discrete:
            action = jnp.zeros((), jnp.int32)
        else:
            action = jnp.zeros((env.spec.act_dim,))
        state, obs, reward, done, truncated = env.step(state, action)
        assert obs.shape == (env.spec.obs_dim,)
        assert jnp.isfinite(reward)


def test_env_vmappable_over_population():
    env = make("pendulum")
    keys = jax.random.split(KEY, 8)
    states, obs = jax.vmap(env.reset)(keys)
    actions = jnp.zeros((8, 1))
    states, obs, rew, done, truncated = jax.vmap(env.step)(states, actions)
    assert obs.shape == (8, 3) and rew.shape == (8,)


def test_episode_auto_resets():
    env = make("reacher")
    state, obs = env.reset(KEY)
    step = jax.jit(env.step)
    for _ in range(105):  # episode length 100
        state, obs, r, done, truncated = step(state, jnp.ones((2,)))
    assert int(state["t"]) <= 100


def test_rollout_and_agents_improve_loss():
    env = make("pendulum")
    agent = td3.init(KEY, env.spec.obs_dim, env.spec.act_dim)
    traj = jax.jit(lambda p, k: rollout(
        env, lambda pp, o, kk: td3.policy(pp, o, kk), p, k, 64))(
            agent.actor, KEY)
    assert traj["obs"].shape == (64, 3)
    batch = {k: v for k, v in traj.items()}
    upd = jax.jit(td3.update)
    losses = []
    st = agent
    for i in range(20):
        st, m = upd(st, batch, None)
        losses.append(float(m["critic_loss"]))
    assert losses[-1] < losses[0]


def test_sac_dqn_single_updates():
    b = {"obs": jax.random.normal(KEY, (8, 3)),
         "action": jax.random.uniform(KEY, (8, 1), minval=-1, maxval=1),
         "reward": jnp.ones((8,)), "next_obs": jax.random.normal(KEY, (8, 3)),
         "done": jnp.zeros((8,))}
    s = sac.init(KEY, 3, 1)
    s, m = jax.jit(sac.update)(s, b, None)
    assert np.isfinite(float(m["critic_loss"]))
    d = dqn.init(KEY, 4, 2)
    bd = dict(b, obs=jax.random.normal(KEY, (8, 4)),
              next_obs=jax.random.normal(KEY, (8, 4)),
              action=jnp.zeros((8,), jnp.int32))
    d, md = jax.jit(dqn.update)(d, bd, None)
    assert np.isfinite(float(md["loss"]))


def test_replay_buffer_population_vmap():
    n, cap = 3, 32
    bufs = jax.vmap(lambda _: buffer_init(
        cap, {"x": jnp.zeros((2,), jnp.float32)}))(jnp.arange(n))
    batch = {"x": jax.random.normal(KEY, (n, 4, 2))}
    bufs = jax.vmap(buffer_add)(bufs, batch)
    assert int(bufs.total[0]) == 4
    keys = jax.random.split(KEY, n)
    samples = jax.vmap(lambda b, k: buffer_sample(b, k, 8))(bufs, keys)
    assert samples["x"].shape == (n, 8, 2)


def test_lm_pipeline_deterministic_and_resumable():
    g1 = host_batches(100, 2, 16, seed=7, shard=0)
    g2 = host_batches(100, 2, 16, seed=7, shard=0)
    a, b = next(g1), next(g2)
    np.testing.assert_array_equal(a, b)
    # restart stability: start_step=1 reproduces the second batch
    second = next(g1)
    g3 = host_batches(100, 2, 16, seed=7, shard=0, start_step=1)
    np.testing.assert_array_equal(second, next(g3))
    # different shards differ
    g4 = host_batches(100, 2, 16, seed=7, shard=1)
    assert not np.array_equal(a, next(g4))


def test_prefetcher_and_double_buffer():
    it = iter(range(100))
    pf = Prefetcher(lambda: np.asarray([next(it)]), depth=2)
    vals = [int(next(pf)[0]) for _ in range(5)]
    assert vals == [0, 1, 2, 3, 4]
    pf.close()
    db = DoubleBuffer(iter([np.ones(2), np.zeros(2), np.ones(2)]))
    out = next(db)
    assert isinstance(out, jax.Array)
