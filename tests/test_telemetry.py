"""repro.telemetry acceptance: schema'd rows survive the JSONL round-trip,
the background writer thread is where device values become host bytes (the
main thread can stay under ``transfer_guard('disallow')`` while writing),
phase timers accumulate and clear per iteration, the compat compile
listener counts XLA compiles with honest attribution labels, and a REAL
short PBT run produces a log from which ``tools/report.py`` reconstructs
the full family tree, per-member hyper trajectories, per-phase timings and
compile counts."""
import importlib.util
import json
import sys
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HyperSpace, PopulationConfig
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3
from repro.telemetry import (CSVSink, ConsoleSink, JSONLSink, LatencyWindow,
                             MultiSink, NullSink, ROW_KINDS, RunTelemetry,
                             validate_row)

_spec = importlib.util.spec_from_file_location(
    "report", Path(__file__).resolve().parents[1] / "tools" / "report.py")
report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(report)


# ------------------------------------------------------------------ sinks
def test_jsonl_roundtrip_every_known_kind(tmp_path):
    """One schema-valid row of every registered kind survives the JSONL
    round-trip bit-exact (and the loader sees them in write order)."""
    samples = {
        "run": {"run_id": "r1"},
        "iter": {"step": 0, "phases": {"update": 0.5}},
        "members": {"step": 0, "fitness": [1.0, 2.0]},
        "evolve": {"step": 2, "parents": [1, 1, 0]},
        "compile": {"event": "backend_compile_duration", "secs": 0.1,
                    "label": "warmup"},
        "ckpt": {"step": 4, "secs": 0.01},
        "serve": {"count": 3, "p50_ms": 1.0, "p99_ms": 2.0},
        "promotion": {"step": 4, "members": [0, 2]},
        "engine": {"algo": "ModuleAgent"},
        "profile": {"action": "start"},
        "bench": {"bench": "actor_loop"},
    }
    assert set(samples) == set(ROW_KINDS)
    path = tmp_path / "t.jsonl"
    with JSONLSink(path, strict=True) as sink:
        for kind, body in samples.items():
            sink.write(dict(body, kind=kind, t=1.0))
    rows = report.load_rows(path)
    assert rows == [dict(b, kind=k, t=1.0) for k, b in samples.items()]
    assert report.check_rows(rows) == []


def test_sink_stamps_missing_t(tmp_path):
    with JSONLSink(tmp_path / "t.jsonl") as sink:
        sink.write({"kind": "custom"})
        sink.write({"kind": "custom"})
    t = [r["t"] for r in report.load_rows(tmp_path / "t.jsonl")]
    assert all(isinstance(x, float) for x in t) and t[0] <= t[1]


def test_close_drains_background_thread(tmp_path):
    """Everything written before close() is on disk after close() —
    the writer thread is drained, not abandoned."""
    path = tmp_path / "t.jsonl"
    sink = JSONLSink(path)
    for i in range(500):
        sink.write({"kind": "custom", "i": i})
    sink.close()
    rows = report.load_rows(path)
    assert [r["i"] for r in rows] == list(range(500))


def test_device_fetch_happens_on_worker_thread(tmp_path):
    """THE design point: the main thread writes rows carrying live jax
    arrays while holding transfer_guard('disallow'); the sink's worker
    thread (where the guard, being thread-local, does not apply) fetches
    them.  This is what lets the fused-call transfer-guard tests run with
    a live sink attached."""
    path = tmp_path / "t.jsonl"
    arr = jnp.arange(4.0) + 1.0
    jax.block_until_ready(arr)
    with JSONLSink(path, strict=True) as sink:
        with jax.transfer_guard("disallow"):
            sink.write({"kind": "iter", "step": 0,
                        "phases": {}, "metrics": {"loss": arr},
                        "scalar": arr.sum()})
            sink.flush()   # worker converted while we stayed guarded
    (row,) = report.load_rows(path)
    assert row["metrics"]["loss"] == [1.0, 2.0, 3.0, 4.0]
    assert row["scalar"] == 10.0


def test_nonfinite_floats_are_stringified(tmp_path):
    with JSONLSink(tmp_path / "t.jsonl") as sink:
        sink.write({"kind": "custom", "bad": float("nan"),
                    "worse": np.float32("inf")})
    (row,) = report.load_rows(tmp_path / "t.jsonl")   # still valid JSON
    assert row["bad"] == "nan" and row["worse"] == "inf"


def test_validate_row_and_strict_close(tmp_path):
    assert validate_row({"kind": "iter", "t": 0.0, "step": 1,
                         "phases": {}}) is None
    assert "lacks required fields" in validate_row(
        {"kind": "evolve", "t": 0.0, "step": 1})
    assert "kind" in validate_row({"t": 0.0})
    # non-strict: invalid rows are dropped, the run survives
    sink = JSONLSink(tmp_path / "drop.jsonl")
    sink.write({"kind": "evolve"})      # missing step/parents
    sink.write({"kind": "custom"})
    sink.close()
    assert len(report.load_rows(sink.path)) == 1
    # strict: close() raises, naming the offense
    strict = JSONLSink(tmp_path / "strict.jsonl", strict=True)
    strict.write({"kind": "evolve"})
    with pytest.raises(ValueError, match="evolve row lacks"):
        strict.close()


def test_csv_sink_one_file_per_kind(tmp_path):
    with CSVSink(tmp_path / "run.csv") as sink:
        sink.write({"kind": "iter", "t": 0.0, "step": 0,
                    "phases": {"u": 0.5}})
        sink.write({"kind": "iter", "t": 1.0, "step": 1,
                    "phases": {"u": 0.6}, "extra": 9})   # projected away
        sink.write({"kind": "ckpt", "t": 2.0, "step": 1, "secs": 0.1})
    it = (tmp_path / "run.iter.csv").read_text().splitlines()
    assert it[0] == "kind,t,step,phases"
    assert len(it) == 3 and it[2].startswith("iter,1.0,1,")
    assert (tmp_path / "run.ckpt.csv").exists()


def test_console_sink_throttles_and_quiets(capsys):
    with ConsoleSink(every=2) as sink:
        for step in range(4):
            sink.write({"kind": "iter", "t": 0.0, "step": step,
                        "phases": {}})
        sink.write({"kind": "evolve", "t": 0.5, "step": 4,
                    "parents": [1, 0]})
        sink.write({"kind": "compile", "t": 0.6, "event": "e", "secs": 0.1,
                    "label": "warmup"})
    out = capsys.readouterr().out
    assert "[iter 0]" in out and "[iter 2]" in out
    assert "[iter 1]" not in out and "[iter 3]" not in out
    assert "parents=[1, 0]" in out          # identities, not mean/max
    assert "compile" not in out             # QUIET kind: JSONL-only


def test_multisink_fans_out(tmp_path):
    a, b = JSONLSink(tmp_path / "a.jsonl"), JSONLSink(tmp_path / "b.jsonl")
    with MultiSink([a, b]) as sink:
        sink.write({"kind": "custom", "x": 1})
    rows_a, rows_b = report.load_rows(a.path), report.load_rows(b.path)
    strip = lambda rows: [{k: v for k, v in r.items() if k != "t"}
                          for r in rows]   # each sink stamps its own t
    assert strip(rows_a) == strip(rows_b) == [{"kind": "custom", "x": 1}]


# ----------------------------------------------------------- RunTelemetry
def test_disabled_telemetry_is_inert():
    tel = RunTelemetry(None)
    assert not tel.enabled and isinstance(tel.sink, NullSink)
    with tel.phase("update"):
        pass
    tel.record_iteration(0, metrics={"x": 1})
    tel.record_evolve(0, [0, 1])
    tel.close()   # nothing registered, nothing raised


def test_phase_timers_accumulate_and_clear(tmp_path):
    tel = RunTelemetry(JSONLSink(tmp_path / "t.jsonl", strict=True))
    for _ in range(2):                    # re-entry accumulates
        with tel.phase("update"):
            time.sleep(0.01)
    with tel.phase("evolve"):
        time.sleep(0.005)
    tel.record_iteration(0)
    tel.record_iteration(1)               # phases were cleared
    tel.close()
    rows = [r for r in report.load_rows(tmp_path / "t.jsonl")
            if r["kind"] == "iter"]
    assert rows[0]["phases"]["update"] >= 0.02
    assert rows[0]["phases"]["evolve"] >= 0.005
    assert rows[1]["phases"] == {}
    # row timestamps are monotone within one producer
    ts = [r["t"] for r in report.load_rows(tmp_path / "t.jsonl")]
    assert ts == sorted(ts)


def test_compile_listener_counts_labels_and_unregisters(tmp_path):
    from repro import compat
    if compat.register_compile_listener(lambda e, s: None) is None:
        pytest.skip("jax.monitoring not available")
    tel = RunTelemetry(JSONLSink(tmp_path / "t.jsonl", strict=True))

    jax.jit(lambda x: x * 2.0 + 1.0)(jnp.arange(3.0)).block_until_ready()
    assert tel.compile_count >= 1
    warm = tel.compile_count

    tel.record_iteration(0)               # warmup -> steady flip
    with tel.compile_scope("resize"):
        jax.jit(lambda x: x * 3.0 - 7.0)(jnp.arange(3.0)).block_until_ready()
    assert tel.compile_count > warm
    after_scope = tel.compile_count

    tel.close()                           # unregisters the listener
    jax.jit(lambda x: x * 5.0 + 11.0)(jnp.arange(3.0)).block_until_ready()
    assert tel.compile_count == after_scope

    labels = [r["label"] for r in report.load_rows(tmp_path / "t.jsonl")
              if r["kind"] == "compile"]
    assert set(labels) == {"warmup", "resize"}
    assert labels[:warm] == ["warmup"] * warm


def test_record_iteration_keeps_device_values_raw(tmp_path):
    """did_update may be a device scalar; record_iteration must not
    bool() it on the caller's thread (that would sync inside the guarded
    train loop)."""
    tel = RunTelemetry(JSONLSink(tmp_path / "t.jsonl", strict=True))
    flag = jnp.asarray(True)
    jax.block_until_ready(flag)
    with jax.transfer_guard("disallow"):
        tel.record_iteration(0, did_update=flag)
    tel.close()
    (row,) = [r for r in report.load_rows(tmp_path / "t.jsonl")
              if r["kind"] == "iter"]
    assert row["did_update"] is True


# --------------------------------------------------------- latency window
def test_latency_window_percentiles_and_fill():
    w = LatencyWindow()
    for ms in range(1, 101):
        w.add(ms / 1e3, fill=0.5, requests=2)
    w.observe_queue(3)
    w.observe_queue(7)
    s = w.summary()
    assert s["count"] == 100 and s["requests"] == 200
    assert s["p50_ms"] == pytest.approx(50.5, abs=1.0)
    assert s["p99_ms"] == pytest.approx(99.0, abs=1.5)
    assert s["fill"] == 0.5 and s["queue_depth_max"] == 7
    w.reset()
    assert w.count == 0 and w.summary()["p50_ms"] is None


# ------------------------------------------------- a real short PBT run
@pytest.fixture(scope="module")
def pbt_log(tmp_path_factory):
    """~6 fused iterations of TD3-PBT on pendulum with a live JSONL sink
    and checkpointing — the log every reconstruction test replays."""
    log_dir = tmp_path_factory.mktemp("pbt_log")
    env = make("pendulum")
    pcfg = PopulationConfig(
        size=4, strategy="pbt", num_steps=2, pbt_interval=2,
        hyper_space=HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),)),
        fitness_window=2, donate=False)
    tel = RunTelemetry(JSONLSink(log_dir / "telemetry.jsonl", strict=True),
                       meta={"algo": "td3", "env": "pendulum"})
    tr = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                    pcfg, seed=0, checkpoint_dir=str(log_dir / "ckpt"),
                    telemetry=tel)
    tr.attach_rollout(env, num_envs=2, collect_steps=16, batch_size=16,
                      eval_envs=1, eval_steps=10)
    tr.run_env_loop(6, eval_every=1)
    tr.save(blocking=True)
    tel.close()
    return report.load_rows(log_dir / "telemetry.jsonl")


def test_pbt_log_is_schema_valid_and_complete(pbt_log):
    assert report.check_rows(pbt_log) == []
    kinds = {r["kind"] for r in pbt_log}
    assert {"run", "engine", "iter", "members", "evolve",
            "ckpt"} <= kinds
    (run,) = [r for r in pbt_log if r["kind"] == "run"]
    assert run["meta"]["algo"] == "td3" and run["jax"] == jax.__version__
    (eng,) = [r for r in pbt_log if r["kind"] == "engine"]
    assert eng["population"] == 4 and eng["experience"] == "replay"


def test_pbt_log_phase_timings_reconstruct(pbt_log):
    phases = report.phase_summary(pbt_log)
    # iterate every iteration; eval every iteration; evolve on cadence
    assert phases["iterate"]["iters"] == 6
    assert phases["eval"]["iters"] == 6
    assert phases["evolve"]["iters"] == 3
    assert all(d["secs"] > 0 for d in phases.values())
    iters = [r for r in pbt_log if r["kind"] == "iter"]
    assert [r["step"] for r in iters] == list(range(6))
    assert all(isinstance(r["metrics"]["critic_loss"], list)
               for r in iters)


def test_pbt_log_lineage_tree_reconstructs(pbt_log):
    evolves = [r for r in pbt_log if r["kind"] == "evolve"]
    assert [e["step"] for e in evolves] == [2, 4, 6]
    assert all(len(e["parents"]) == 4 and e["strategy"] == "PBT"
               for e in evolves)
    roots, children, current = report.lineage_tree(pbt_log)
    # replay the events by hand: the tree's live node per slot must match
    state = {i: (i, 0) for i in range(4)}
    for e in evolves:
        prev = dict(state)
        for i, p in enumerate(e["parents"]):
            if p != i:
                state[i] = (i, e["step"])
                assert (i, e["step"]) in children.get(prev[p], []) \
                    or p < 0
    assert current == state
    # every non-root node is some node's child, exactly once
    kids = [k for v in children.values() for k in v]
    assert len(kids) == len(set(kids))
    tree = "\n".join(report.render_tree(roots, children, current))
    for slot, node in current.items():
        assert f"m{node[0]}@{node[1]} *" in tree


def test_pbt_log_hyper_trajectories_reconstruct(pbt_log):
    traj = report.hyper_trajectories(pbt_log)
    assert set(traj) == {"actor_lr"}
    series = traj["actor_lr"]
    assert all(len(vals) == 4 for _, vals in series)
    # the @0 snapshot is the sampled prior; post-evolve snapshots exist
    assert series[0][0] == 0
    assert {s for s, _ in series} >= {0, 2, 4, 6}
    fits = report.fitness_series(pbt_log)
    assert len(fits) == 6 and all(len(v) == 4 for _, v in fits)


def test_pbt_log_compiles_and_ckpt(pbt_log):
    compiles = report.compile_summary(pbt_log)
    assert compiles.get("warmup", {}).get("count", 0) > 0
    # evolve executables are labeled, not lumped into steady-state noise
    assert compiles.get("steady", {}).get("count", 0) == 0
    ckpts = [r for r in pbt_log if r["kind"] == "ckpt"]
    assert len(ckpts) == 1 and ckpts[0]["secs"] > 0
    assert ckpts[0]["blocking"] is True


def test_report_renders_and_check_passes(pbt_log, tmp_path, capsys):
    import io
    buf = io.StringIO()
    report.report(pbt_log, out=buf)
    text = buf.getvalue()
    for section in ("phases", "compiles", "family tree", "lineage",
                    "hyper actor_lr", "checkpoints"):
        assert section in text
    # --check exit codes: 0 on the real log, 1 when a row is broken
    p = tmp_path / "log.jsonl"
    p.write_text("\n".join(json.dumps(r) for r in pbt_log) + "\n")
    assert report.main([str(p), "--check"]) == 0
    capsys.readouterr()
    p.write_text('{"kind": "evolve", "t": 1.0}\n')
    assert report.main([str(p), "--check"]) == 1


def test_checkpoint_header_carries_run_id(pbt_log, tmp_path_factory):
    """CheckpointManager run_meta: the saved extras point back at the
    telemetry run that produced them."""
    from repro.checkpoint import CheckpointManager
    (run,) = [r for r in pbt_log if r["kind"] == "run"]
    log_root = Path(tmp_path_factory.getbasetemp())
    ckpt_dirs = list(log_root.glob("pbt_log*/ckpt"))
    assert ckpt_dirs, "fixture saved a checkpoint"
    mgr = CheckpointManager(str(ckpt_dirs[0]))
    extra = mgr.peek_extra(mgr.latest())
    assert extra["run"]["run_id"] == run["run_id"]
