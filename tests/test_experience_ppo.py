"""Experience-pipeline + PPO acceptance: GAE vs a pure-Python reference on
hand-built episodes, trajectory-buffer mechanics, collector extras,
pop-vectorized PPO vs a single-agent reference bit-for-bit, the fused
on-policy iteration's no-host-round-trip property, backend parity, the
algorithm registry, and the fused population-Adam path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PopulationConfig
from repro.data import (compute_gae, traj_add, traj_full, traj_init,
                        traj_reset, trajectory_spec, transition_spec)
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer, PPOAgent, make_update
from repro.rl import ppo

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------- GAE
def _gae_ref(r, v, nv, done, ep_end, gamma, lam):
    """Pure-Python GAE on 1-D arrays (the textbook backward recursion)."""
    T = len(r)
    adv = np.zeros(T)
    last = 0.0
    for t in reversed(range(T)):
        delta = r[t] + gamma * nv[t] * (1 - done[t]) - v[t]
        last = delta + gamma * lam * (1 - ep_end[t]) * last
        adv[t] = last
    return adv, adv + v


def test_gae_matches_python_reference_on_hand_built_episodes():
    """One rollout containing every boundary case: a true termination at
    t=2 (no bootstrap, chain cut), a time-limit truncation at t=5
    (bootstrap from the pre-reset next value, chain STILL cut), and an
    unfinished episode at the rollout edge (bootstrap from nv[-1])."""
    gamma, lam = 0.95, 0.9
    r = np.array([1.0, -0.5, 2.0, 0.3, 0.1, 1.5, -1.0, 0.7])
    v = np.array([0.2, 0.4, -0.1, 0.8, 0.5, 0.3, 0.6, -0.2])
    nv = np.array([0.4, -0.1, 9.9, 0.5, 0.3, 1.7, -0.2, 0.9])
    done = np.array([0, 0, 1, 0, 0, 0, 0, 0], np.float64)
    trunc = np.array([0, 0, 0, 0, 0, 1, 0, 0], np.float64)
    ep_end = np.maximum(done, trunc)

    want_adv, want_ret = _gae_ref(r, v, nv, done, ep_end, gamma, lam)
    adv, ret = compute_gae(*(jnp.asarray(x, jnp.float32) for x in
                             (r, v, nv, done, ep_end)), gamma, lam)
    np.testing.assert_allclose(np.asarray(adv), want_adv, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(ret), want_ret, rtol=1e-5,
                               atol=1e-6)
    # the termination really cut the chain: everything at t <= 2 is
    # independent of rewards after it
    r2 = r.copy()
    r2[3:] += 100.0
    adv2, _ = compute_gae(jnp.asarray(r2, jnp.float32),
                          *(jnp.asarray(x, jnp.float32) for x in
                            (v, nv, done, ep_end)), gamma, lam)
    np.testing.assert_allclose(np.asarray(adv2[:3]), np.asarray(adv[:3]),
                               rtol=1e-6)
    # and the truncated step bootstraps: zeroing nv[5] changes adv[5]
    nv3 = nv.copy()
    nv3[5] = 0.0
    adv3, _ = compute_gae(*(jnp.asarray(x, jnp.float32) for x in
                            (r, v, nv3, done, ep_end)), gamma, lam)
    assert abs(float(adv3[5]) - float(adv[5])) > 1e-3


def test_gae_matches_reference_on_collected_cartpole_rollout():
    """End-to-end: GAE over a REAL collected trajectory (cartpole
    terminates within the window) equals the python reference fed the
    stored rewards/values and eagerly recomputed next-values."""
    env = make("cartpole")
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim, discrete=True)
    tr = PopTrainer(agent, PopulationConfig(size=2, strategy="none",
                                            donate=False), seed=3)
    engine = tr.attach_rollout(env, num_envs=2, collect_steps=40,
                               batch_size=40, epochs=1, eval_envs=1,
                               eval_steps=5)
    tr.env_iteration()
    buf0 = jax.tree.map(lambda x: np.asarray(x[0]), engine.bufs.data)
    assert buf0["done"].sum() > 0  # random cartpole fails within 40 steps
    params0 = jax.tree.map(lambda x: x[0], tr.actors)
    nv = np.asarray(ppo.value(params0, jnp.asarray(buf0["next_obs"])))
    gamma, lam = 0.99, 0.95
    for e in range(2):
        ep_end = np.maximum(buf0["done"][:, e], buf0["truncated"][:, e])
        want_adv, want_ret = _gae_ref(
            buf0["reward"][:, e], buf0["value"][:, e], nv[:, e],
            buf0["done"][:, e], ep_end, gamma, lam)
        adv, ret = compute_gae(
            *(jnp.asarray(buf0[k][:, e]) for k in ("reward", "value")),
            jnp.asarray(nv[:, e]), jnp.asarray(buf0["done"][:, e]),
            jnp.asarray(ep_end), gamma, lam)
        np.testing.assert_allclose(np.asarray(adv), want_adv, rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(ret), want_ret, rtol=1e-4,
                                   atol=1e-5)


# ------------------------------------------------------- trajectory buffer
def test_trajectory_buffer_mechanics_and_spec_filtering():
    spec = trajectory_spec(make("pendulum").spec)
    buf = traj_init(4, 2, spec)
    assert not bool(traj_full(buf))
    step = {k: jnp.full((1, 2) + tuple(s.shape), 1.0, s.dtype)
            for k, s in spec.items()}
    step["bogus_extra"] = jnp.zeros((1, 2))  # dropped, not stored
    buf = traj_add(buf, step)
    assert int(buf.pos) == 1 and "bogus_extra" not in buf.data
    two = {k: jnp.stack([v[0]] * 3) * 2.0 for k, v in step.items()
           if k != "bogus_extra"}
    buf = traj_add(buf, two)
    assert int(buf.pos) == 4 and bool(traj_full(buf))
    np.testing.assert_array_equal(np.asarray(buf.data["reward"]),
                                  [[1, 1], [2, 2], [2, 2], [2, 2]])
    buf = traj_reset(buf)
    assert int(buf.pos) == 0 and not bool(traj_full(buf))
    # replay spec is unchanged by the pipeline refactor
    assert set(transition_spec(make("pendulum").spec)) == {
        "obs", "action", "reward", "next_obs", "done"}


def test_collector_records_policy_extras():
    """The generalized collector stores what the policy emits: PPO's
    log_prob/value extras come back time-major and agree with an eager
    recomputation from the stored (obs, action)."""
    env = make("pendulum")
    n, T, E = 2, 5, 3
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim)
    tr = PopTrainer(agent, PopulationConfig(size=n, strategy="none",
                                            donate=False), seed=1)
    engine = tr.attach_rollout(env, num_envs=E, collect_steps=T,
                               batch_size=T * E, epochs=1, eval_envs=1,
                               eval_steps=5)
    k = jax.random.PRNGKey(9)
    _, traj = engine.collector.collect(tr.actors, engine.vstate, k, T,
                                       None, flat=False)
    assert traj["log_prob"].shape == (n, T, E)
    assert traj["value"].shape == (n, T, E)
    for i in range(n):
        params = jax.tree.map(lambda x: x[i], tr.actors)
        obs = traj["obs"][i].reshape(T * E, -1)
        act = traj["action"][i].reshape(T * E, -1)
        logp, _ = ppo.log_prob_entropy(params, obs, act)
        np.testing.assert_allclose(
            np.asarray(traj["log_prob"][i]).reshape(-1), np.asarray(logp),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(traj["value"][i]).reshape(-1),
            np.asarray(ppo.value(params, obs)), rtol=1e-5, atol=1e-5)


# ------------------------------------------------- single-agent bit-parity
def _ppo_trainer(seed_others, hypers_others):
    """A 3-member fused PPO run where member 0 is FIXED (params from seed
    7, pinned hypers) and members 1..2 vary with the arguments."""
    env = make("pendulum")
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim)
    tr = PopTrainer(agent, PopulationConfig(size=3, strategy="none",
                                            donate=False), seed=7)
    alt = agent.population_init(jax.random.PRNGKey(seed_others), 3)
    tr.state = jax.tree.map(lambda a, b: a.at[1:].set(b[1:]), tr.state, alt)
    tr.hypers = {"lr": jnp.asarray([3e-4] + hypers_others["lr"]),
                 "clip_eps": jnp.asarray([0.2] + hypers_others["clip_eps"])}
    tr.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=8,
                      epochs=2, eval_envs=1, eval_steps=5)
    return tr


def test_pop_vectorized_ppo_matches_single_agent_bit_for_bit():
    """The paper's central claim, for the on-policy pipeline, at the
    strictest possible tolerance: under the vectorized backend a member's
    training is a pure function of that member's own inputs, so member 0 —
    identical params, hypers and member key in both runs — must come out
    BIT-identical no matter what the rest of the population is doing.
    Run B is therefore a single-agent PPO reference for member 0, merely
    embedded in an unrelated population."""
    tr_a = _ppo_trainer(11, {"lr": [1e-4, 5e-4], "clip_eps": [0.1, 0.3]})
    tr_b = _ppo_trainer(29, {"lr": [9e-4, 2e-5], "clip_eps": [0.25, 0.15]})
    for _ in range(3):
        ma, _, _ = tr_a.env_iteration()
        mb, _, _ = tr_b.env_iteration()
    for la, lb in zip(jax.tree.leaves(tr_a.state),
                      jax.tree.leaves(tr_b.state)):
        np.testing.assert_array_equal(np.asarray(la)[0], np.asarray(lb)[0])
    # and the members that DID differ actually diverged (the test bites)
    diff = any(np.any(np.asarray(la)[1] != np.asarray(lb)[1])
               for la, lb in zip(jax.tree.leaves(tr_a.state),
                                 jax.tree.leaves(tr_b.state)))
    assert diff


def test_ppo_vectorized_matches_sequential_backend():
    """The literal single-agent program: the sequential backend runs ONE
    jit'd per-member update looped over members.  Same GAE batches through
    both backends agree to fp-reassociation tolerance (repo precedent:
    vmapped batched matmuls reassociate reductions)."""
    env = make("pendulum")
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim)
    n, B, K = 3, 8, 2
    state = agent.population_init(KEY, n)
    batch = {
        "obs": jax.random.normal(KEY, (K, n, B, env.spec.obs_dim)),
        "action": jax.random.normal(KEY, (K, n, B, env.spec.act_dim)),
        "log_prob": 0.1 * jax.random.normal(KEY, (K, n, B)),
        "value": jax.random.normal(KEY, (K, n, B)),
        "advantage": jax.random.normal(KEY, (K, n, B)),
        "return": jax.random.normal(KEY, (K, n, B)),
    }
    hypers = {"lr": jnp.asarray([3e-4, 1e-4, 5e-4]),
              "clip_eps": jnp.asarray([0.2, 0.1, 0.3])}
    sv, mv = make_update(agent, "vectorized", num_steps=K,
                         donate=False)(state, batch, hypers)
    ss, ms = make_update(agent, "sequential", num_steps=K)(
        state, batch, hypers)
    for a, b in zip(jax.tree.leaves(sv.params), jax.tree.leaves(ss.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)
    np.testing.assert_allclose(np.asarray(mv["policy_loss"]),
                               np.asarray(ms["policy_loss"]), atol=1e-5)


# --------------------------------------------------- no host round-trips
def test_fused_onpolicy_iteration_is_one_jit_call_no_transfers():
    """The acceptance property: after warm-up, a fused on-policy iteration
    (collect -> GAE -> epoch/minibatch updates) runs as the one compiled
    callable with NO implicit host<->device transfer — enforced by
    jax.transfer_guard, which raises on any hidden round-trip."""
    env = make("pendulum")
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim)
    tr = PopTrainer(agent, PopulationConfig(size=2, strategy="none",
                                            donate=False), seed=0)
    engine = tr.attach_rollout(env, num_envs=2, collect_steps=8,
                               batch_size=8, epochs=2, eval_envs=1,
                               eval_steps=5)
    tr.env_iteration()   # compile outside the guard
    with jax.transfer_guard("disallow"):
        metrics, stats, did = tr.env_iteration()
    # results stayed on device (materializing them now is the caller's
    # explicit choice, outside the fused call)
    assert isinstance(metrics["policy_loss"], jax.Array)
    assert np.isfinite(np.asarray(metrics["policy_loss"])).all()
    # the off-policy engine holds the same property (regression)
    from repro.rl import td3
    tro = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                     PopulationConfig(size=2, strategy="none", num_steps=2,
                                      donate=False), seed=0)
    tro.attach_rollout(env, num_envs=2, collect_steps=8, batch_size=8,
                       buffer_capacity=64, eval_envs=1, eval_steps=5)
    tro.env_iteration()
    with jax.transfer_guard("disallow"):
        tro.env_iteration()


def test_onpolicy_minibatch_validation():
    env = make("pendulum")
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim)
    tr = PopTrainer(agent, PopulationConfig(size=2, strategy="none",
                                            donate=False), seed=0)
    with pytest.raises(ValueError, match="must divide"):
        tr.attach_rollout(make("pendulum"), num_envs=2, collect_steps=8,
                          batch_size=7)


# ---------------------------------------------------------------- registry
def test_algo_registry_rejects_unknown_and_validates_action_space():
    from repro.rl import ALGOS, get_algo, make_agent
    assert set(ALGOS) == {"td3", "sac", "dqn", "ppo"}
    with pytest.raises(ValueError, match=r"registered: \['dqn', 'ppo'"):
        get_algo("a2c")
    cont, disc = make("pendulum").spec, make("cartpole").spec
    with pytest.raises(ValueError, match="continuous action space"):
        make_agent("td3", disc)
    with pytest.raises(ValueError, match="discrete action space"):
        make_agent("dqn", cont)
    ag = make_agent("ppo", disc)
    assert ag.experience_kind == "trajectory"
    assert make_agent("sac", cont).experience_kind == "replay"


def test_train_cli_algo_smoke(tmp_path):
    from repro.launch.train import main
    best = main(["--algo", "ppo", "--env", "pendulum", "--population", "2",
                 "--steps", "2", "--num-envs", "2", "--collect-steps", "8",
                 "--batch", "8", "--epochs", "1", "--eval-every", "1",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "100",
                 "--resume", "none"])
    assert np.isfinite(best)
    with pytest.raises(ValueError, match="registered"):
        main(["--algo", "nope"])


# ----------------------------------------------------- fused population-Adam
def _stacked_trees(key, n):
    def one(k):
        k1, k2 = jax.random.split(k)
        return {"w": jax.random.normal(k1, (5, 7)),
                "b": jax.random.normal(k2, (7,))}
    return jax.vmap(one)(jax.random.split(key, n))


@pytest.mark.parametrize("fused", [False, True])
def test_population_adam_matches_stock_vmapped_adam(fused):
    """Numerics parity of the kernels/pop_adam wiring: the jnp fallback is
    the stock optimizer's expressions (tight tolerance), the forced-kernel
    path runs interpret mode off-TPU (fp-rounding tolerance)."""
    from repro.optim import adam, apply_updates, population_adam
    n = 3
    params = _stacked_trees(KEY, n)
    grads = _stacked_trees(jax.random.PRNGKey(1), n)
    lr = jnp.asarray([1e-3, 3e-4, 1e-4])

    si, su = adam(3e-4)
    sp, ss = params, jax.vmap(si)(params)
    for _ in range(3):
        upd, ss = jax.vmap(lambda g, o, l: su(g, o, lr_override=l))(
            grads, ss, lr)
        sp = apply_updates(sp, upd)

    pi, pa = population_adam(3e-4, fused=fused)
    p, st = params, pi(params)
    for _ in range(3):
        p, st = pa(p, grads, st, lr_override=lr)
    tol = dict(rtol=1e-6, atol=1e-7) if not fused \
        else dict(rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(sp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    np.testing.assert_array_equal(np.asarray(st.step), [3, 3, 3])
    for a, b in zip(jax.tree.leaves(st.nu), jax.tree.leaves(ss.nu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


def test_shared_critic_fused_adam_flag_and_parity():
    """PopulationConfig.fused_adam reaches the shared-critic policy step
    and changes nothing numerically (off-TPU it is the jnp fallback)."""
    from repro.core import shared
    n, B, OBS, ACT = 4, 8, 3, 1
    st = shared.init(KEY, OBS, ACT, n)
    batch = {"obs": jax.random.normal(KEY, (n, B, OBS)),
             "action": jax.random.normal(KEY, (n, B, ACT)),
             "reward": jax.random.normal(KEY, (n, B)),
             "next_obs": jax.random.normal(KEY, (n, B, OBS)),
             "done": jnp.zeros((n, B))}
    s0, _ = jax.jit(shared.make_shared_critic_update())(st, batch, None)
    s1, _ = jax.jit(shared.make_shared_critic_update(fused_adam=True))(
        st, batch, None)
    for a, b in zip(jax.tree.leaves(s0.policies),
                    jax.tree.leaves(s1.policies)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    from repro.pop import SharedCriticAgent
    ag = SharedCriticAgent(OBS, ACT)
    PopTrainer(ag, PopulationConfig(size=n, strategy="none",
                                    fused_adam=True, donate=False), seed=0)
    assert ag.fused_adam is True
