"""Population sharded over a device mesh + on-device PBT exchange
(core/distributed.py), on an 8-host-device mesh in a subprocess."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_host_mesh
from repro.core.distributed import (population_sharding, shard_population,
                                    population_axes)
from repro.core import population_init, pbt_step, sample_hypers, vectorized_update
from repro.configs.base import HyperSpace, PopulationConfig
from repro.rl import td3
from repro import compat

mesh = make_host_mesh(model=1, data=8)
N = 8
key = jax.random.PRNGKey(0)
pop = population_init(lambda k: td3.init(k, 3, 1), key, N)
pop = shard_population(pop, mesh)
sh = population_sharding(pop, mesh)
# leading population axis is sharded over the data axis
leaf_sh = jax.tree.leaves(sh)[0]
assert "data" in str(leaf_sh.spec), leaf_sh.spec

space = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),))
hypers = sample_hypers(key, space, N)
batch = {
 "obs": jax.random.normal(key, (N, 16, 3)),
 "action": jax.random.uniform(key, (N, 16, 1), minval=-1, maxval=1),
 "reward": jax.random.normal(key, (N, 16)),
 "next_obs": jax.random.normal(key, (N, 16, 3)),
 "done": jnp.zeros((N, 16)),
}
with compat.set_mesh(mesh):
    update = vectorized_update(td3.update, donate=False)
    pop2, metrics = update(pop, batch, hypers)
    # PBT across the sharded population: the member gathers lower to
    # XLA collectives under jit
    pcfg = PopulationConfig(size=N, exploit_frac=0.25, hyper_space=space)
    fitness = jnp.arange(N, dtype=jnp.float32)
    step = jax.jit(lambda k, p, h, f: pbt_step(k, p, h, f, pcfg))
    pop3, hyp3, parents = step(key, pop2, hypers, fitness)
    lowered = jax.jit(lambda k, p, h, f: pbt_step(k, p, h, f, pcfg)).lower(
        key, pop2, hypers, fitness).compile()
hlo = lowered.as_text()
has_collective = any(c in hlo for c in ("all-gather", "all-reduce",
                                        "collective-permute", "all-to-all"))
print(json.dumps({
    "parents": np.asarray(parents).tolist(),
    "pbt_has_collective": bool(has_collective),
    "critic_loss_finite": bool(np.isfinite(float(metrics["critic_loss"][0]))),
}))
"""


@pytest.mark.slow
def test_population_sharded_update_and_pbt_exchange():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["critic_loss_finite"]
    assert out["pbt_has_collective"], \
        "sharded-population PBT should lower to XLA collectives"
    # worst members (0,1) must take parents from the top-25% (6,7)
    assert all(p in (6, 7) for p in out["parents"][:2])
    assert out["parents"][2:] == [2, 3, 4, 5, 6, 7]
