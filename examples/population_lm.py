"""The paper's technique on a language model: PBT over a population of
reduced-config LMs, one vectorized update stream, with checkpointing.

This is the bridge between the paper's RL setting (§5.1) and the
framework's LM scale-out (EXPERIMENTS.md §Population): the exact same
``repro.pop`` machinery drives both — this script is nothing but a config
for the unified train driver.

    PYTHONPATH=src python examples/population_lm.py
"""
from repro.launch import train

if __name__ == "__main__":
    train.main(["--arch", "qwen2_0_5b", "--smoke", "--population", "4",
                "--steps", "60", "--batch", "4", "--seq-len", "64",
                "--pbt-interval", "20", "--ckpt-dir", "/tmp/population_lm",
                "--resume", "none"])
