"""DvD case study (paper §5.3) via the unified API: population TD3 + the
determinant diversity term.

``strategy="dvd"`` installs the §B.2 diversity-coefficient schedule on the
shared-critic agent — selection pressure comes from the joint -logdet(RBF
kernel) term inside the actor loss, so the evolve step is the identity.
Acting runs through the ``repro.rollout`` fused iteration (per-member
batched envs + device-resident buffers + chained updates in one jitted
call); the behavior probe for the diversity diagnostic is sampled from the
engine's replay buffers.  Swapping to ``strategy="pbt"`` (one line) trades
the diversity loss for exploit/explore selection over the same population.

    PYTHONPATH=src python examples/dvd.py [--population 5] [--iters 20]
"""
import argparse
import time

import jax

from repro.configs.base import PopulationConfig
from repro.core.dvd import behavior_embedding, dvd_loss
from repro.envs import make
from repro.pop import PopTrainer, SharedCriticAgent
from repro.rl import networks as nets
from repro.telemetry import make_telemetry


def run(population=5, iters=20, collect_steps=100, updates_per_iter=32,
        strategy="dvd", seed=0, log_dir=None):
    env = make("reacher")  # multi-goal env where diversity matters
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    n = population

    pcfg = PopulationConfig(size=n, strategy=strategy, dvd_period=400,
                            num_steps=updates_per_iter, pbt_interval=1,
                            exploit_frac=0.2, fitness_window=1)
    telemetry = make_telemetry(log_dir, console_every=1,
                               meta={"example": "dvd", "population": n,
                                     "strategy": strategy})
    trainer = PopTrainer(SharedCriticAgent(obs_dim, act_dim), pcfg, seed=seed,
                         telemetry=telemetry)
    engine = trainer.attach_rollout(env, num_envs=2,
                                    collect_steps=collect_steps,
                                    batch_size=128, buffer_capacity=50_000,
                                    eval_envs=2)

    key = jax.random.PRNGKey(seed + 1)
    t0 = time.time()
    result = {"best": float("nan")}

    def on_iter(it, metrics, stats, fitness, lineage):
        nonlocal key
        key, kp = jax.random.split(key)
        result["best"] = float(fitness.max())
        probe = engine.probe_obs(kp, 20)
        emb = behavior_embedding(nets.actor_apply, trainer.actors, probe)
        # the §5.3 diagnostic: ensemble volume of the probe behaviors,
        # an example-specific row through the shared pipe
        telemetry.record("diversity", step=it + 1,
                         logdet=-dvd_loss(emb))

    trainer.run_env_loop(iters, eval_every=1, on_iter=on_iter)
    telemetry.record("run_end", best_fitness=result["best"],
                     secs=round(time.time() - t0, 2))
    telemetry.close()
    return result["best"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--strategy", default="dvd", choices=["dvd", "pbt", "none"])
    ap.add_argument("--log-dir", default=None,
                    help="also write DIR/telemetry.jsonl (tools/report.py)")
    args = ap.parse_args()
    run(population=args.population, iters=args.iters, strategy=args.strategy,
        log_dir=args.log_dir)
