"""DvD case study (paper §5.3) via the unified API: population TD3 + the
determinant diversity term.

``strategy="dvd"`` installs the §B.2 diversity-coefficient schedule on the
shared-critic agent — selection pressure comes from the joint -logdet(RBF
kernel) term inside the actor loss, so the evolve step is the identity.
Swapping to ``strategy="pbt"`` (one line) trades the diversity loss for
exploit/explore selection over the same population.

    PYTHONPATH=src python examples/dvd.py [--population 5] [--iters 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import PopulationConfig
from repro.core.dvd import behavior_embedding, dvd_loss
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.pop import PopTrainer, SharedCriticAgent
from repro.rl import networks as nets
from repro.rl import td3


def run(population=5, iters=20, collect_steps=200, updates_per_iter=32,
        strategy="dvd", seed=0):
    env = make("reacher")  # multi-goal env where diversity matters
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    key = jax.random.PRNGKey(seed)
    n = population

    pcfg = PopulationConfig(size=n, strategy=strategy, dvd_period=400,
                            pbt_interval=updates_per_iter, exploit_frac=0.2,
                            fitness_window=updates_per_iter)
    trainer = PopTrainer(SharedCriticAgent(obs_dim, act_dim), pcfg, seed=seed)

    buf = buffer_init(50_000, {
        "obs": jnp.zeros((obs_dim,)), "action": jnp.zeros((act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((obs_dim,)),
        "done": jnp.zeros(())})
    collect = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, td3.policy, a, k, collect_steps)
    )(actors, keys))

    returns = None
    t0 = time.time()
    for it in range(iters):
        key, k1, k2 = jax.random.split(key, 3)
        traj = collect(trainer.actors, jax.random.split(k1, n))
        buf = buffer_add(buf, jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj))
        returns = traj["reward"].sum(-1)
        for _ in range(updates_per_iter):
            key, ks = jax.random.split(key)
            batch = jax.vmap(lambda kk: buffer_sample(buf, kk, 128))(
                jax.random.split(ks, n))
            trainer.step(batch, fitness=returns)
        probe = buffer_sample(buf, k2, 20)["obs"]
        emb = behavior_embedding(nets.actor_apply, trainer.actors, probe)
        print(f"iter {it + 1}: best return {float(returns.max()):+.2f} "
              f"diversity {-float(dvd_loss(emb)):.3f} "
              f"({time.time() - t0:.1f}s)", flush=True)
    return float(returns.max())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--strategy", default="dvd", choices=["dvd", "pbt", "none"])
    args = ap.parse_args()
    run(population=args.population, iters=args.iters, strategy=args.strategy)
