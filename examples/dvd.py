"""DvD case study (paper §5.3): population TD3 + determinant diversity term.

Same shared-critic machinery as CEM-RL; the actor loss gets the joint
-logdet(RBF kernel) diversity term over behavioral embeddings with the
paper's §B.2 schedule for the coefficient.

    PYTHONPATH=src python examples/dvd.py [--population 5] [--iters 20]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvd import dvd_coef_schedule, behavior_embedding, dvd_loss
from repro.core.shared import init as shared_init, make_shared_critic_update
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.rl import networks as nets
from repro.rl import td3


def run(population=5, iters=20, collect_steps=200, updates_per_iter=32,
        seed=0):
    env = make("reacher")  # multi-goal env where diversity matters
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    key = jax.random.PRNGKey(seed)
    n = population

    st = shared_init(key, obs_dim, act_dim, n)
    update = jax.jit(make_shared_critic_update(
        dvd_coef_fn=lambda s: dvd_coef_schedule(s, period=400)))
    buf = buffer_init(50_000, {
        "obs": jnp.zeros((obs_dim,)), "action": jnp.zeros((act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((obs_dim,)),
        "done": jnp.zeros(())})
    collect = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, td3.policy, a, k, collect_steps)
    )(actors, keys))

    t0 = time.time()
    for it in range(iters):
        key, k1, k2 = jax.random.split(key, 3)
        traj = collect(st.policies, jax.random.split(k1, n))
        buf = buffer_add(buf, jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj))
        returns = traj["reward"].sum(-1)
        for _ in range(updates_per_iter):
            key, ks = jax.random.split(key)
            batch = jax.vmap(lambda kk: buffer_sample(buf, kk, 128))(
                jax.random.split(ks, n))
            st, m = update(st, batch, None)
        probe = buffer_sample(buf, k2, 20)["obs"]
        emb = behavior_embedding(nets.actor_apply, st.policies, probe)
        print(f"iter {it + 1}: best return {float(returns.max()):+.2f} "
              f"diversity {-float(dvd_loss(emb)):.3f} "
              f"({time.time() - t0:.1f}s)", flush=True)
    return float(returns.max())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()
    run(population=args.population, iters=args.iters)
