"""CEM-RL case study (paper §5.2), vectorized per §4.2.

CEM maintains a gaussian over policy parameters; each iteration samples N
policies, trains half of them with TD3 against ONE shared critic (the
population-averaged critic loss — the paper's second-order modification),
evaluates everyone, and refits the distribution on the elite half.

    PYTHONPATH=src python examples/cemrl.py [--population 10] [--iters 20]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cem_init, cem_sample, cem_update
from repro.core.shared import SharedCriticState, init as shared_init, \
    make_shared_critic_update
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.rl import networks as nets
from repro.rl import td3


def run(population=10, iters=20, rl_steps=64, collect_steps=200, seed=0):
    env = make("pendulum")
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    key = jax.random.PRNGKey(seed)
    n, half = population, population // 2

    st = shared_init(key, obs_dim, act_dim, half)
    cem_state, unravel = cem_init(
        jax.tree.map(lambda x: x[0], st.policies), sigma_init=1e-2)
    update = jax.jit(make_shared_critic_update())
    buf = buffer_init(50_000, {
        "obs": jnp.zeros((obs_dim,)), "action": jnp.zeros((act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((obs_dim,)),
        "done": jnp.zeros(())})

    evaluate = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, lambda p, o, kk: td3.policy(
            p, o, None), a, k, collect_steps))(actors, keys))
    unravel_n = jax.jit(jax.vmap(unravel))

    t0 = time.time()
    for it in range(iters):
        key, k1, k2, k3 = jax.random.split(key, 4)
        flat = cem_sample(k1, cem_state, n)              # (N, P)
        policies = unravel_n(flat)

        # half the population undergoes TD3 updates w/ the shared critic
        trainees = jax.tree.map(lambda x: x[:half], policies)
        st = st._replace(policies=trainees,
                         target_policies=jax.tree.map(jnp.copy, trainees))
        for j in range(rl_steps):
            key, ks = jax.random.split(key)
            if int(buf.total) >= 256:
                batch = jax.vmap(lambda kk: buffer_sample(buf, kk, 128))(
                    jax.random.split(ks, half))
                st, _ = update(st, batch, None)
        policies = jax.tree.map(
            lambda tr, al: jnp.concatenate([tr, al[half:]]), st.policies,
            policies)

        traj = evaluate(policies, jax.random.split(k2, n))
        buf = buffer_add(buf, jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj))
        returns = traj["reward"].sum(-1)
        flat_trained = jax.vmap(
            lambda p: jax.flatten_util.ravel_pytree(p)[0])(policies)
        cem_state = cem_update(cem_state, flat_trained, returns)

        mean_return = float(jnp.mean(returns))
        print(f"iter {it + 1}: mean return {mean_return:+.2f} "
              f"best {float(returns.max()):+.2f} "
              f"sigma {float(jnp.mean(cem_state.var)):.2e} "
              f"({time.time() - t0:.1f}s)", flush=True)
    return mean_return


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=10)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()
    run(population=args.population, iters=args.iters)
