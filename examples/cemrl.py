"""CEM-RL case study (paper §5.2), vectorized per §4.2, via the unified API.

CEM maintains a gaussian over policy parameters.  Each iteration the
population (drawn from that distribution) trains HALF its members with TD3
against ONE shared critic (``train_frac=0.5``, CEM-RL Algorithm 1) — the
paper's second-order modification averages the critic loss over the trainees
so the whole update is a single compiled call — then everyone is evaluated
and ``CEM.evolve`` refits the distribution on the elite half and redraws the
members.  Acting goes through ``repro.rollout``: the fused iteration
collects into per-member device-resident buffers and chains ``rl_steps``
shared-critic updates, and Algorithm 1's train -> evaluate -> refit ordering
is exactly ``run_env_loop`` with ``pbt_interval=1``.  Swapping
``backend="vectorized"`` for ``"sequential"`` runs the ORIGINAL CEM-RL
interleaved ordering (the paper's baseline arm); swapping ``strategy="cem"``
for ``"pbt"`` turns the same loop into PBT over the shared-critic
population.

    PYTHONPATH=src python examples/cemrl.py [--population 10] [--iters 20]
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs.base import PopulationConfig
from repro.envs import make
from repro.pop import PopTrainer, SharedCriticAgent
from repro.telemetry import make_telemetry


def run(population=10, iters=20, rl_steps=64, collect_steps=100,
        strategy="cem", backend="vectorized", seed=0, log_dir=None):
    env = make("pendulum")
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    n = population

    # pbt_interval=1: evolve fires every iteration, AFTER the post-training
    # evaluation (Algorithm 1 ordering: sample -> train half -> evaluate all
    # -> refit on what was evaluated)
    pcfg = PopulationConfig(size=n, strategy=strategy, backend=backend,
                            num_steps=rl_steps, pbt_interval=1,
                            elite_frac=0.5, sigma_init=1e-2,
                            fitness_window=1)
    telemetry = make_telemetry(log_dir, console_every=1,
                               meta={"example": "cemrl", "population": n,
                                     "strategy": strategy})
    trainer = PopTrainer(SharedCriticAgent(obs_dim, act_dim, train_frac=0.5),
                         pcfg, seed=seed, telemetry=telemetry)
    trainer.attach_rollout(env, num_envs=2, collect_steps=collect_steps,
                           batch_size=128, buffer_capacity=50_000,
                           eval_envs=2)

    t0 = time.time()
    result = {"mean": float("nan")}

    def on_iter(it, metrics, stats, fitness, lineage):
        result["mean"] = float(jnp.mean(fitness))
        if strategy == "cem":
            # distribution contraction — CEM's own health signal, emitted
            # as an example-specific row through the same pipe
            telemetry.record(
                "cem", step=it + 1,
                sigma=float(jnp.mean(trainer.strategy.cem_state.var)))

    trainer.run_env_loop(iters, eval_every=1, on_iter=on_iter)
    telemetry.record("run_end", mean_fitness=result["mean"],
                     secs=round(time.time() - t0, 2))
    telemetry.close()
    return result["mean"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=10)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--strategy", default="cem", choices=["cem", "pbt", "none"])
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential"])
    ap.add_argument("--log-dir", default=None,
                    help="also write DIR/telemetry.jsonl (tools/report.py)")
    args = ap.parse_args()
    run(population=args.population, iters=args.iters,
        strategy=args.strategy, backend=args.backend, log_dir=args.log_dir)
