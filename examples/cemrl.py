"""CEM-RL case study (paper §5.2), vectorized per §4.2, via the unified API.

CEM maintains a gaussian over policy parameters.  Each iteration the
population (drawn from that distribution) trains HALF its members with TD3
against ONE shared critic (``train_frac=0.5``, CEM-RL Algorithm 1) — the
paper's second-order modification averages the critic loss over the trainees
so the whole update is a single compiled call — then everyone is evaluated
and ``CEM.evolve`` refits the distribution on the elite half and redraws the
members.  Swapping ``backend="vectorized"`` for ``"sequential"`` runs the
ORIGINAL CEM-RL interleaved ordering (the paper's baseline arm); swapping
``strategy="cem"`` for ``"pbt"`` turns the same loop into PBT over the
shared-critic population.

    PYTHONPATH=src python examples/cemrl.py [--population 10] [--iters 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import PopulationConfig
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.pop import PopTrainer, SharedCriticAgent
from repro.rl import td3


def run(population=10, iters=20, rl_steps=64, collect_steps=200,
        strategy="cem", backend="vectorized", seed=0):
    env = make("pendulum")
    obs_dim, act_dim = env.spec.obs_dim, env.spec.act_dim
    key = jax.random.PRNGKey(seed)
    n = population

    # pbt_interval=0: the CEM refit is driven explicitly below, AFTER the
    # post-training evaluation (Algorithm 1 ordering: sample -> train half
    # -> evaluate all -> refit on what was evaluated)
    pcfg = PopulationConfig(size=n, strategy=strategy, backend=backend,
                            pbt_interval=0, elite_frac=0.5, sigma_init=1e-2,
                            fitness_window=1)
    trainer = PopTrainer(SharedCriticAgent(obs_dim, act_dim, train_frac=0.5),
                         pcfg, seed=seed)

    buf = buffer_init(50_000, {
        "obs": jnp.zeros((obs_dim,)), "action": jnp.zeros((act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((obs_dim,)),
        "done": jnp.zeros(())})
    evaluate = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, lambda p, o, kk: td3.policy(
            p, o, None), a, k, collect_steps))(actors, keys))

    mean_return = float("nan")
    t0 = time.time()
    for it in range(iters):
        key, k2 = jax.random.split(key)

        # 1. train: TD3 updates of the first half against the shared critic
        for _ in range(rl_steps):
            key, kb = jax.random.split(key)
            if int(buf.total) < 256:
                break
            batch = jax.vmap(lambda kk: buffer_sample(buf, kk, 128))(
                jax.random.split(kb, n))
            trainer.step(batch)

        # 2. evaluate everyone AFTER training (these returns belong to the
        #    parameters the refit will flatten)
        traj = evaluate(trainer.actors, jax.random.split(k2, n))
        buf = buffer_add(buf, jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj))
        returns = traj["reward"].sum(-1)

        # 3. refit the distribution on the elites and redraw the members
        trainer.report_fitness(returns)
        trainer.evolve()

        mean_return = float(jnp.mean(returns))
        sigma = float(jnp.mean(trainer.strategy.cem_state.var)) \
            if strategy == "cem" else float("nan")
        print(f"iter {it + 1}: mean return {mean_return:+.2f} "
              f"best {float(returns.max()):+.2f} "
              f"sigma {sigma:.2e} "
              f"({time.time() - t0:.1f}s)", flush=True)
    return mean_return


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=10)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--strategy", default="cem", choices=["cem", "pbt", "none"])
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential"])
    args = ap.parse_args()
    run(population=args.population, iters=args.iters,
        strategy=args.strategy, backend=args.backend)
