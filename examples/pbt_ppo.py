"""PBT over population-vectorized PPO — the on-policy end of the pipeline.

The GPU-accelerated PBT benchmarks this repo positions against (Shahid et
al. 2024; Jaderberg et al.'s original PBT) tune PPO, not replay-buffer
algorithms; this example is that scenario on the shared experience
pipeline: the SAME ``PopTrainer.attach_rollout`` call site as
``pbt_td3.py``, but the agent declares ``experience_kind="trajectory"`` so
the fused iteration becomes collect (recording each member's log_prob /
value extras) -> on-device GAE -> shuffled epoch/minibatch updates — still
ONE jitted donated call per iteration.

PBT tunes the per-member ``lr`` / ``clip_eps`` / ``entropy_coef`` (the
update side) and ``gae_lambda`` (the advantage side) — all dynamic inputs
to the one compiled iteration, never a recompile.

    PYTHONPATH=src python examples/pbt_ppo.py [--population 8] [--iters 40]
"""
import argparse
import time

import numpy as np

from repro.configs.base import HyperSpace, PopulationConfig
from repro.envs import make
from repro.pop import PopTrainer, PPOAgent
from repro.telemetry import make_telemetry

SPACE = HyperSpace(
    log_uniform=(("lr", 1e-5, 1e-3),),
    uniform=(("clip_eps", 0.1, 0.3), ("entropy_coef", 0.0, 0.03),
             ("gae_lambda", 0.9, 1.0)))


def run(population=8, iters=40, num_envs=8, collect_steps=64,
        epochs=4, batch_size=128, pbt_every=5, backend="vectorized",
        env_name="pendulum", ckpt_dir="/tmp/pbt_ppo_ckpt", seed=0,
        log_dir=None):
    env = make(env_name)
    n = population
    pcfg = PopulationConfig(
        size=n, strategy="pbt", backend=backend, pbt_interval=pbt_every,
        exploit_frac=0.3, hyper_space=SPACE, fitness_window=5,
        donate=False)  # async checkpoints read the state
    agent = PPOAgent(env.spec.obs_dim, env.spec.act_dim,
                     discrete=env.spec.discrete)
    # iter rows carry the PPO metrics (approx_kl included); the console
    # sink is the one formatting path, --log-dir keeps the JSONL record
    telemetry = make_telemetry(log_dir, console_every=10,
                               meta={"example": "pbt_ppo", "population": n,
                                     "env": env_name, "backend": backend})
    trainer = PopTrainer(agent, pcfg, seed=seed, checkpoint_dir=ckpt_dir,
                         telemetry=telemetry)
    # on-policy knobs: each iteration consumes the whole fresh rollout of
    # collect_steps x num_envs transitions as epochs x minibatches
    trainer.attach_rollout(env, num_envs=num_envs,
                           collect_steps=collect_steps,
                           batch_size=batch_size, epochs=epochs, eval_envs=2)

    t0 = time.time()
    last = {"fitness": None}

    def on_iter(it, metrics, stats, fitness, lineage):
        if fitness is not None:
            last["fitness"] = fitness
        if (it + 1) % 10 == 0:
            trainer.save()

    trainer.run_env_loop(iters, eval_every=1, on_iter=on_iter)
    trainer.wait()
    if last["fitness"] is None:
        last["fitness"] = np.asarray(trainer.evaluate_fitness())
    best = float(np.max(last["fitness"]))
    telemetry.record("run_end", best_fitness=best,
                     secs=round(time.time() - t0, 2),
                     compiles=telemetry.compile_count)
    telemetry.close()
    return best


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=8)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--env", default="pendulum",
                    choices=["pendulum", "reacher", "cartpole",
                             "mountain_car", "acrobot"])
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential", "sharded",
                             "islands"])
    ap.add_argument("--log-dir", default=None,
                    help="also write DIR/telemetry.jsonl (tools/report.py)")
    args = ap.parse_args()
    run(population=args.population, iters=args.iters, env_name=args.env,
        backend=args.backend, log_dir=args.log_dir)
