"""End-to-end PBT case study (paper §5.1), scaled to this machine.

Trains a population of TD3 agents on the pure-JAX pendulum environment with
the full production loop through ``PopTrainer``: vectorized data collection
-> per-member replay buffers -> chained vectorized update steps
(``num_steps`` in the config) -> on-device PBT exploit/explore ->
checkpointing.  The same script trains a single-seed baseline by passing
``--population 1`` — no separate code path.

    PYTHONPATH=src python examples/pbt_td3.py [--population 8] [--iters 30]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HyperSpace, PopulationConfig
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3

SPACE = HyperSpace(
    log_uniform=(("actor_lr", 3e-5, 3e-3), ("critic_lr", 3e-5, 3e-3)),
    uniform=(("policy_freq", 0.2, 1.0), ("noise", 0.0, 1.0),
             ("discount", 0.9, 1.0)))


def run(population=8, iters=30, steps_per_iter=128, batch_size=128,
        pbt_every=10, backend="vectorized", ckpt_dir="/tmp/pbt_td3_ckpt",
        seed=0):
    env = make("pendulum")
    key = jax.random.PRNGKey(seed)
    n = population
    pcfg = PopulationConfig(
        size=n, strategy="pbt", backend=backend,
        num_steps=steps_per_iter // 2, pbt_interval=pbt_every,
        exploit_frac=0.3, hyper_space=SPACE, fitness_window=5, donate=False)
    trainer = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                         pcfg, seed=seed, checkpoint_dir=ckpt_dir)

    bufs = jax.vmap(lambda _: buffer_init(20_000, {
        "obs": jnp.zeros((env.spec.obs_dim,)),
        "action": jnp.zeros((env.spec.act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((env.spec.obs_dim,)),
        "done": jnp.zeros(())}))(jnp.arange(n))

    collect = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, td3.policy, a, k, steps_per_iter)
    )(actors, keys))
    sample = jax.jit(jax.vmap(lambda b, k: jax.vmap(
        lambda kk: buffer_sample(b, kk, batch_size)
    )(jax.random.split(k, steps_per_iter // 2))))

    returns = None
    t0 = time.time()
    for it in range(iters):
        key, kc, ks = jax.random.split(key, 3)
        traj = collect(trainer.actors, jax.random.split(kc, n))
        bufs = jax.vmap(buffer_add)(bufs, traj)
        returns = traj["reward"].sum(-1) * (200 / steps_per_iter)

        batches = sample(bufs, jax.random.split(ks, n))
        # batches: (n, k, B, ...) -> (k, n, B, ...) for the chained protocol
        batches = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batches)
        _, lineage = trainer.step(batches, fitness=returns)

        if lineage is not None:
            fit = trainer.last_fitness
            print(f"[pbt] iter {it + 1} fitness best={float(fit.max()):+.1f} "
                  f"parents={np.asarray(lineage)}")
        if (it + 1) % 10 == 0:
            trainer.save()
            print(f"iter {it + 1}: best return {float(returns.max()):+.2f} "
                  f"mean {float(returns.mean()):+.2f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    trainer.wait()
    best = float(np.max(np.asarray(returns)))
    print(f"done: best final return {best:+.2f} in {time.time() - t0:.1f}s")
    return best


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=8)
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential", "sharded"])
    args = ap.parse_args()
    run(population=args.population, iters=args.iters, backend=args.backend)
