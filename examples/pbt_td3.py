"""End-to-end PBT case study (paper §5.1), scaled to this machine.

Trains a population of TD3 agents on the pure-JAX pendulum environment with
the full production loop: ``PopTrainer`` owns the update/evolve side and the
``repro.rollout`` engine owns the acting side — per-member batched envs,
population replay buffers, and the FUSED collect -> insert -> sample ->
update iteration, so one jitted call per iteration runs without leaving the
device.  Per-member exploration noise comes from each member's PBT-tuned
``explore_noise`` hyperparameter; fitness comes from the deterministic
evaluator.
The same script trains a single-seed baseline by passing ``--population 1``
— no separate code path.

    PYTHONPATH=src python examples/pbt_td3.py [--population 8] [--iters 30]
"""
import argparse
import time

import numpy as np

from repro.configs.base import HyperSpace, PopulationConfig
from repro.envs import make
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3
from repro.telemetry import make_telemetry

# "noise" is TD3's target-policy-smoothing sigma (update side);
# "explore_noise" drives the Collector's acting-time gaussian — separate
# hypers so PBT can anneal exploration without touching the critic targets
SPACE = HyperSpace(
    log_uniform=(("actor_lr", 3e-5, 3e-3), ("critic_lr", 3e-5, 3e-3)),
    uniform=(("policy_freq", 0.2, 1.0), ("noise", 0.0, 1.0),
             ("explore_noise", 0.0, 1.0), ("discount", 0.9, 1.0)))


def run(population=8, iters=30, num_envs=4, collect_steps=32,
        updates_per_iter=64, batch_size=128, pbt_every=10,
        backend="vectorized", ckpt_dir="/tmp/pbt_td3_ckpt", seed=0,
        log_dir=None):
    env = make("pendulum")
    n = population
    pcfg = PopulationConfig(
        size=n, strategy="pbt", backend=backend, num_steps=updates_per_iter,
        pbt_interval=pbt_every, exploit_frac=0.3, hyper_space=SPACE,
        fitness_window=5, donate=False)  # async checkpoints read the state
    # evolve / members / ckpt rows print through the one console
    # formatting path; --log-dir additionally writes the JSONL record
    # tools/report.py replays into the full family tree
    telemetry = make_telemetry(log_dir, console_every=5,
                               meta={"example": "pbt_td3", "population": n,
                                     "backend": backend})
    trainer = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                         pcfg, seed=seed, checkpoint_dir=ckpt_dir,
                         telemetry=telemetry)
    trainer.attach_rollout(env, num_envs=num_envs,
                           collect_steps=collect_steps,
                           batch_size=batch_size, buffer_capacity=20_000,
                           eval_envs=2)

    t0 = time.time()
    last = {"fitness": None}

    def on_iter(it, metrics, stats, fitness, lineage):
        if fitness is not None:
            last["fitness"] = fitness
        if (it + 1) % 10 == 0:
            trainer.save()

    # eval_every=2 with fitness_window=5 and pbt_interval=10: exactly the
    # five evals PBT will consume land in the window each evolve cycle —
    # evaluating every iteration would just feed the deque's trash can
    trainer.run_env_loop(iters, eval_every=2, on_iter=on_iter)
    trainer.wait()
    if last["fitness"] is None:  # iters < eval_every: score the pop now
        last["fitness"] = np.asarray(trainer.evaluate_fitness())
    best = float(np.max(last["fitness"]))
    telemetry.record("run_end", best_fitness=best,
                     secs=round(time.time() - t0, 2),
                     compiles=telemetry.compile_count)
    telemetry.close()
    return best


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=8)
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--backend", default="vectorized",
                    choices=["vectorized", "sequential", "sharded",
                             "islands"])
    ap.add_argument("--log-dir", default=None,
                    help="also write DIR/telemetry.jsonl (tools/report.py)")
    args = ap.parse_args()
    run(population=args.population, iters=args.iters, backend=args.backend,
        log_dir=args.log_dir)
