"""End-to-end PBT case study (paper §5.1), scaled to this machine.

Trains a population of TD3 agents on the pure-JAX pendulum environment with
the full production loop: vectorized data collection -> per-member replay
buffers -> chained vectorized update steps -> on-device PBT exploit/explore
-> checkpointing.  A single-seed baseline (population of 1, default hypers)
runs alongside for the paper's performance-vs-walltime comparison.

    PYTHONPATH=src python examples/pbt_td3.py [--population 8] [--iters 30]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import HyperSpace, PopulationConfig
from repro.core import (pbt_step, population_init, sample_hypers,
                        vectorized_update)
from repro.data import buffer_add, buffer_init, buffer_sample
from repro.envs import make, rollout
from repro.rl import td3

SPACE = HyperSpace(
    log_uniform=(("actor_lr", 3e-5, 3e-3), ("critic_lr", 3e-5, 3e-3)),
    uniform=(("policy_freq", 0.2, 1.0), ("noise", 0.0, 1.0),
             ("discount", 0.9, 1.0)))


def run(population=8, iters=30, steps_per_iter=128, batch_size=128,
        pbt_every=10, ckpt_dir="/tmp/pbt_td3_ckpt", seed=0):
    env = make("pendulum")
    key = jax.random.PRNGKey(seed)
    n = population
    pcfg = PopulationConfig(size=n, exploit_frac=0.3, hyper_space=SPACE)

    pop = population_init(lambda k: td3.init(k, env.spec.obs_dim,
                                             env.spec.act_dim), key, n)
    hypers = sample_hypers(key, SPACE, n) if n > 1 else None
    bufs = jax.vmap(lambda _: buffer_init(20_000, {
        "obs": jnp.zeros((env.spec.obs_dim,)),
        "action": jnp.zeros((env.spec.act_dim,)),
        "reward": jnp.zeros(()), "next_obs": jnp.zeros((env.spec.obs_dim,)),
        "done": jnp.zeros(())}))(jnp.arange(n))

    collect = jax.jit(lambda actors, keys: jax.vmap(
        lambda a, k: rollout(env, td3.policy, a, k, steps_per_iter)
    )(actors, keys))
    update = vectorized_update(td3.update, num_steps=steps_per_iter // 2,
                               donate=False)
    sample = jax.jit(jax.vmap(lambda b, k: jax.vmap(
        lambda kk: buffer_sample(b, kk, batch_size)
    )(jax.random.split(k, steps_per_iter // 2))))

    mgr = CheckpointManager(ckpt_dir, keep=2)
    fitness_hist = []
    t0 = time.time()
    for it in range(iters):
        key, kc, ks = jax.random.split(key, 3)
        traj = collect(pop.actor, jax.random.split(kc, n))
        bufs = jax.vmap(buffer_add)(bufs, traj)
        returns = traj["reward"].sum(-1) * (200 / steps_per_iter)
        fitness_hist.append(np.asarray(returns))

        batches = sample(bufs, jax.random.split(ks, n))
        # batches: (n, k, B, ...) -> (k, n, B, ...) for the chained protocol
        batches = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batches)
        pop, metrics = update(pop, batches, hypers)

        if n > 1 and (it + 1) % pbt_every == 0:
            fit = jnp.asarray(np.mean(fitness_hist[-5:], axis=0))
            key, kp = jax.random.split(key)
            pop, hypers, parents = pbt_step(kp, pop, hypers, fit, pcfg)
            print(f"[pbt] iter {it + 1} fitness best={float(fit.max()):+.1f} "
                  f"parents={np.asarray(parents)}")
        if (it + 1) % 10 == 0:
            mgr.save_async(it, pop)
            print(f"iter {it + 1}: best return {float(returns.max()):+.2f} "
                  f"mean {float(returns.mean()):+.2f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    mgr.wait()
    best = float(np.max(fitness_hist[-1]))
    print(f"done: best final return {best:+.2f} in {time.time() - t0:.1f}s")
    return best


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", type=int, default=8)
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()
    run(population=args.population, iters=args.iters)
