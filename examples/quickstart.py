"""Quickstart: the paper's protocol in ~40 lines.

Train a population of 8 TD3 agents with per-member hyperparameters using ONE
compiled vectorized update step, on data collected from the pure-JAX
pendulum env.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import HyperSpace
from repro.core import population_init, sample_hypers, vectorized_update
from repro.envs import make, rollout
from repro.rl import td3

N = 8
env = make("pendulum")
key = jax.random.PRNGKey(0)

# 1. a population is the single-agent state with a leading axis
pop = population_init(lambda k: td3.init(k, env.spec.obs_dim,
                                         env.spec.act_dim), key, N)

# 2. per-member hyperparameters are just vmapped leaves
space = HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),
                                ("critic_lr", 3e-5, 3e-3)))
hypers = sample_hypers(key, space, N)

# 3. ONE compiled call updates every member (the paper's Fig. 1, right)
update = vectorized_update(td3.update, num_steps=1, donate=False)

# 4. data collection vectorizes over the population too
collect = jax.jit(lambda actors, keys: jax.vmap(
    lambda a, k: rollout(env, td3.policy, a, k, 256))(actors, keys))

for it in range(10):
    key, kc = jax.random.split(key)
    traj = collect(pop.actor, jax.random.split(kc, N))
    batch = jax.tree.map(lambda x: x[:, -256:], traj)
    pop, metrics = update(pop, batch, hypers)
    print(f"iter {it}: mean reward {float(traj['reward'].mean()):+.3f} "
          f"critic loss {float(metrics['critic_loss'].mean()):.3f}")
print("OK — 8 agents trained in one vectorized stream")
