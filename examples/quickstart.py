"""Quickstart: the paper's protocol through the unified API, in ~30 lines.

Train a population of 8 TD3 agents with per-member hyperparameters using ONE
compiled vectorized update step, on data collected from the pure-JAX
pendulum env.  Swapping the update backend or the evolution strategy is a
one-line change to ``PopulationConfig`` (e.g. ``backend="sequential"`` runs
the paper's baseline arm; ``strategy="cem"`` evolves policy parameters
instead of hyperparameters).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import HyperSpace, PopulationConfig
from repro.envs import make, rollout
from repro.pop import ModuleAgent, PopTrainer
from repro.rl import td3
from repro.telemetry import ConsoleSink, RunTelemetry

N = 8
env = make("pendulum")
key = jax.random.PRNGKey(0)

# 1. one config names the whole setup: size, strategy, backend, hyper priors
pcfg = PopulationConfig(
    size=N, strategy="pbt", backend="vectorized", pbt_interval=5,
    hyper_space=HyperSpace(log_uniform=(("actor_lr", 3e-5, 3e-3),
                                        ("critic_lr", 3e-5, 3e-3))))

# 2. the trainer stacks the population, samples per-member hypers, and
#    compiles ONE update for every member (the paper's Fig. 1, right);
#    telemetry formats every iteration — note the loop below never calls
#    float() on device values, the sink's thread fetches them
telemetry = RunTelemetry(ConsoleSink(every=1), meta={"example": "quickstart"})
trainer = PopTrainer(ModuleAgent(td3, env.spec.obs_dim, env.spec.act_dim),
                     pcfg, seed=0, telemetry=telemetry)

# 3. data collection vectorizes over the population too
collect = jax.jit(lambda actors, keys: jax.vmap(
    lambda a, k: rollout(env, td3.policy, a, k, 256))(actors, keys))

for it in range(10):
    key, kc = jax.random.split(key)
    traj = collect(trainer.actors, jax.random.split(kc, N))
    batch = jax.tree.map(lambda x: x[:, -256:], traj)
    returns = traj["reward"].sum(-1)
    metrics, lineage = trainer.step(batch, fitness=returns)
    telemetry.record("rollout", step=it, mean_reward=traj["reward"].mean())
telemetry.close()
print("OK — 8 agents trained in one vectorized stream")
